"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: Pallas kernels are TPU programs; on the CPU backend of
this container they execute through `interpret=True` (kernel body run
op-by-op — bit-accurate, slow).  Each wrapper therefore routes:

    TPU backend          → compiled Pallas kernel
    elsewhere, validate  → interpret-mode Pallas (tests force this)
    elsewhere, fast path → the jnp oracle from ref.py (identical math)

`force` overrides: "pallas" | "interpret" | "ref" | None (auto).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .adc import adc_dist_pallas
from .pairwise_dist import pairwise_sq_dist_pallas
from .project_dist import project_dist_pallas
from .topk import topk_smallest_pallas

__all__ = ["pairwise_sq_dist", "project_dist", "topk_smallest", "adc_dist"]


def _mode(force: str | None) -> str:
    if force is not None:
        return force
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pairwise_sq_dist(q: jax.Array, x: jax.Array, *, force: str | None = None,
                     **block_kw) -> jax.Array:
    """(B,d) × (N,d) → (B,N) squared Euclidean distances (f32)."""
    mode = _mode(force)
    if mode == "ref":
        return ref.pairwise_sq_dist(q, x)
    return pairwise_sq_dist_pallas(q, x, interpret=(mode == "interpret"), **block_kw)


def project_dist(x: jax.Array, a: jax.Array, qp: jax.Array, *,
                 force: str | None = None, **block_kw) -> jax.Array:
    """Fused (x@a) projected distances to qp: (N,d),(d,m),(B,m) → (B,N)."""
    mode = _mode(force)
    if mode == "ref":
        return ref.project_dist(x, a, qp)
    return project_dist_pallas(x, a, qp, interpret=(mode == "interpret"), **block_kw)


def adc_dist(codes: jax.Array, lut: jax.Array, *, force: str | None = None,
             **block_kw) -> jax.Array:
    """Asymmetric distances: codes (N,S) or (B,N,S) × LUTs (B,S,V) → (B,N).

    Per-query candidate codes (B, N, S) vmap the shared-codes kernel
    over the batch; the ref oracle handles both shapes directly.
    """
    mode = _mode(force)
    if mode == "ref":
        return ref.adc_dist(codes, lut)
    interpret = mode == "interpret"
    if codes.ndim == 3:
        return jax.vmap(
            lambda c, l: adc_dist_pallas(c, l[None], interpret=interpret,
                                         **block_kw)[0]
        )(codes, lut)
    return adc_dist_pallas(codes, lut, interpret=interpret, **block_kw)


def topk_smallest(d: jax.Array, k: int, *, force: str | None = None,
                  **block_kw) -> tuple[jax.Array, jax.Array]:
    """Row-wise k smallest (values, indices), ascending."""
    mode = _mode(force)
    if mode == "ref":
        return ref.topk_smallest(d, k)
    return topk_smallest_pallas(d, k, interpret=(mode == "interpret"), **block_kw)
