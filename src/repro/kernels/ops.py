"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: Pallas kernels are TPU programs; on the CPU backend of
this container they execute through `interpret=True` (kernel body run
op-by-op — bit-accurate, slow).  Each wrapper therefore routes:

    TPU backend          → compiled Pallas kernel
    elsewhere, validate  → interpret-mode Pallas (tests force this)
    elsewhere, fast path → the jnp oracle from ref.py (identical math)

`force` overrides: "pallas" | "interpret" | "ref" | None (auto).

Observability: while ``repro.obs`` tracing is enabled, every dispatch
executed EAGERLY (concrete arguments — i.e. not under an enclosing
jit trace, where wall time is meaningless) records a ``kernel.<op>``
span carrying the op's modeled bytes/FLOPs (``repro.obs.roofline``)
and closes only after ``block_until_ready``, so traces place each
kernel on the roofline.  Disabled cost is one boolean check per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs import roofline as _roofline
from repro.obs import trace as _otrace

from . import ref
from .adc import adc_dist_pallas
from .pair_join import pair_join_pallas
from .pairwise_dist import pairwise_sq_dist_pallas
from .project_dist import project_dist_pallas
from .select import radius_select_pallas
from .topk import topk_smallest_pallas
from .verify import verify_topk_pallas

__all__ = ["pairwise_sq_dist", "project_dist", "topk_smallest", "adc_dist",
           "radius_select", "verify_topk", "pair_join"]


def _mode(force: str | None) -> str:
    if force is not None:
        return force
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _instrumented(name: str, cost_of):
    """Wrap a dispatch in a roofline-annotated kernel span.

    ``cost_of(*args, **kw)`` returns the op's :class:`KernelCost` for
    the call's shapes.  Instrumentation engages only when tracing is
    on AND every argument is concrete (an abstract jax tracer means an
    enclosing jit is tracing this call — timing it would measure trace
    construction, not execution); the span closes after
    ``block_until_ready`` so device time lands inside it.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            if not _otrace.enabled() or not _otrace.concrete(*args):
                return fn(*args, **kw)
            try:
                attrs = cost_of(*args, **kw).attrs()
            except Exception:  # shape we did not model: still time it
                attrs = {}
            with _otrace.get_tracer().span(name, **attrs):
                out = fn(*args, **kw)
                _otrace.block(out)
            return out

        return wrapper

    return deco


def _pairwise_cost(q, x, **kw) -> _roofline.KernelCost:
    if x.ndim == 3:
        B, N, d = x.shape
    else:
        (B, d), N = q.shape, x.shape[0]
    return _roofline.pairwise_sq_dist_cost(B, N, d)


def _project_cost(x, a, qp, **kw) -> _roofline.KernelCost:
    return _roofline.project_dist_cost(x.shape[0], x.shape[1], a.shape[1],
                                       qp.shape[0])


def _adc_cost(codes, lut, **kw) -> _roofline.KernelCost:
    B, S, V = lut.shape
    return _roofline.adc_dist_cost(B, codes.shape[-2], S, V)


def _topk_cost(d, k, **kw) -> _roofline.KernelCost:
    return _roofline.topk_cost(d.shape[0], d.shape[1], k)


def _select_cost(d, T, *, T_pad=None, **kw) -> _roofline.KernelCost:
    B, N = d.shape
    if T_pad is None:
        T_pad = T + max(256, T // 8)
    return _roofline.radius_select_cost(B, N, min(max(T_pad, T), N))


def _verify_cost(data, q, cand, k, **kw) -> _roofline.KernelCost:
    B, Tc = cand.shape
    return _roofline.verify_topk_cost(B, Tc, data.shape[1], k)


def _pair_join_cost(x, key, k, *, block_n=128, **kw) -> _roofline.KernelCost:
    return _roofline.pair_join_cost(x.shape[0], x.shape[1], k,
                                    block_n=block_n)


@_instrumented("kernel.pairwise_sq_dist", _pairwise_cost)
def pairwise_sq_dist(q: jax.Array, x: jax.Array, *, force: str | None = None,
                     **block_kw) -> jax.Array:
    """(B,d) × (N,d) → (B,N) squared Euclidean distances (f32).

    x may be per-query candidate rows (B, N, d) — the gathered VERIFY
    form — in which case the kernel is vmapped over the batch.
    """
    mode = _mode(force)
    if mode == "ref":
        return ref.pairwise_sq_dist(q, x)
    interpret = mode == "interpret"
    if x.ndim == 3:
        return jax.vmap(
            lambda qb, xb: pairwise_sq_dist_pallas(
                qb[None], xb, interpret=interpret, **block_kw)[0]
        )(q, x)
    return pairwise_sq_dist_pallas(q, x, interpret=interpret, **block_kw)


@_instrumented("kernel.project_dist", _project_cost)
def project_dist(x: jax.Array, a: jax.Array, qp: jax.Array, *,
                 force: str | None = None, **block_kw) -> jax.Array:
    """Fused (x@a) projected distances to qp: (N,d),(d,m),(B,m) → (B,N)."""
    mode = _mode(force)
    if mode == "ref":
        return ref.project_dist(x, a, qp)
    return project_dist_pallas(x, a, qp, interpret=(mode == "interpret"), **block_kw)


@_instrumented("kernel.adc_dist", _adc_cost)
def adc_dist(codes: jax.Array, lut: jax.Array, *, force: str | None = None,
             **block_kw) -> jax.Array:
    """Asymmetric distances: codes (N,S) or (B,N,S) × LUTs (B,S,V) → (B,N).

    Per-query candidate codes (B, N, S) vmap the shared-codes kernel
    over the batch; the ref oracle handles both shapes directly.
    """
    mode = _mode(force)
    if mode == "ref":
        return ref.adc_dist(codes, lut)
    interpret = mode == "interpret"
    if codes.ndim == 3:
        return jax.vmap(
            lambda c, l: adc_dist_pallas(c, l[None], interpret=interpret,
                                         **block_kw)[0]
        )(codes, lut)
    return adc_dist_pallas(codes, lut, interpret=interpret, **block_kw)


@_instrumented("kernel.topk_smallest", _topk_cost)
def topk_smallest(d: jax.Array, k: int, *, force: str | None = None,
                  **block_kw) -> tuple[jax.Array, jax.Array]:
    """Row-wise k smallest (values, indices), ascending.

    The streaming selection-network kernel is O(k²) and capped at
    k ≤ 128; larger k transparently routes through the radius-threshold
    selection path (``radius_select``), which has no such cap.
    """
    mode = _mode(force)
    if mode == "ref":
        return ref.topk_smallest(d, k)
    if k > 128:
        return radius_select(d, k, force=force, **block_kw)
    return topk_smallest_pallas(d, k, interpret=(mode == "interpret"), **block_kw)


def default_select_seed(d: jax.Array, T: int, *, stride: int = 8) -> jax.Array:
    """Per-row seed for radius selection from a strided sample of d:
    the sample mean scaled by the target fraction T/N — within the
    rung ladder's reach of the T-th smallest for any unimodal row."""
    samp = d[:, ::stride]
    N = d.shape[1]
    return jnp.mean(samp, axis=1) * jnp.float32(max(T / N, 1e-3))


@_instrumented("kernel.radius_select", _select_cost)
def radius_select(d: jax.Array, T: int, *, tau0: jax.Array | None = None,
                  T_pad: int | None = None, force: str | None = None,
                  with_count: bool = False, **block_kw):
    """Row-wise T smallest (values, indices) by radius thresholding.

    Same contract as :func:`topk_smallest` (ascending, lowest-index
    tie-break) for any T, but O(n) threshold passes + one O(T_pad·T)
    finishing sort instead of an O(n·T) selection — the SELECT step for
    candidate budgets in the thousands.  ``tau0`` (B,) optionally seeds
    the threshold ladder (e.g. the Eq. 9 estimate from
    ``repro.core.fused``); default is a sample-mean seed.

    Exactness matches top_k unconditionally: a tie cluster wider than
    the survivor buffer (see select.py) is detected from the kernel's
    per-row survivor counts and rerouted to the exact sort, so the
    radius path can only ever be a perf win, never a recall loss.
    Degenerate budgets (T_pad ≥ N) fall back to the sort directly.

    ``with_count=True`` appends the per-row survivor count (B,) int32 —
    the realized T under the final threshold, surfaced to callers as
    ``WorkStats.candidates_selected``.  Sort paths (degenerate budget,
    tie-cluster reroute) have no threshold and report the budget T.
    """
    mode = _mode(force)
    B, N = d.shape
    if T_pad is None:
        T_pad = T + max(256, T // 8)
    T_pad = min(max(T_pad, T), N)
    if mode == "ref":
        return ref.radius_select(d, T, T_pad=T_pad, with_count=with_count)
    if T_pad >= N:  # nothing to skip — the plain sort is cheaper
        vals, idx = ref.topk_smallest(d, T)
        if with_count:
            return vals, idx, jnp.full((B,), T, jnp.int32)
        return vals, idx
    if tau0 is None:
        tau0 = default_select_seed(d, T)
    vals_p, idx_p, cnt = radius_select_pallas(
        d, tau0, T, T_pad=T_pad, interpret=(mode == "interpret"), **block_kw)

    def _trim():
        neg, pos = jax.lax.top_k(-vals_p, T)
        return (-neg, jnp.take_along_axis(idx_p, pos, axis=1),
                cnt.astype(jnp.int32))

    # buffer overflow (pathological tie cluster at the threshold) drops
    # survivors in index order — arbitrarily wrong ones — so reroute to
    # the exact sort rather than return a degraded candidate set
    vals, idx, cnt_out = jax.lax.cond(
        jnp.any(cnt > T_pad),
        lambda: ref.topk_smallest(d, T) + (jnp.full((B,), T, jnp.int32),),
        _trim)
    if with_count:
        return vals, idx, cnt_out
    return vals, idx


def pair_join(x, key, k: int, *, thresh2: float, force: str | None = None,
              block_n: int = 128):
    """Top-k closest pairs of x's rows by pruned blockwise self-join.

    x (n, d) sorted ascending by key (n,) → (d² (k,) ascending, pi (k,),
    pj (k,), stats (2,) = [pairs_verified, tiles_pruned]); pi < pj are
    row POSITIONS in the sorted order, (-1, +inf) past the real pair
    count.  ``thresh2`` = (γ·t)² is Algorithm 4's radius filter as tile
    masking; ``float('inf')`` disables pruning (exhaustive exact join).

    k > 128 is outside the in-VMEM selection network's regime and
    routes through the host oracle on every dispatch mode.
    """
    mode = _mode(force)

    def dispatch():
        if mode == "ref" or k > 128:
            return ref.pair_join(x, key, k, thresh2=thresh2, block_n=block_n)
        return pair_join_pallas(x, key, k, thresh2=float(thresh2),
                                block_n=block_n,
                                interpret=(mode == "interpret"))

    if not _otrace.enabled() or not _otrace.concrete(x, key):
        return dispatch()
    # unlike the other ops the join's traffic is data-dependent (the
    # γ·t·ub filter skips tiles), so the span's model is refined
    # post-hoc from the kernel's own tiles_pruned counter
    cost = _pair_join_cost(x, key, k, block_n=block_n)
    with _otrace.get_tracer().span("kernel.pair_join", **cost.attrs()) as sp:
        out = dispatch()
        _otrace.block(out)
        if sp is not None:
            import numpy as _np

            n_ti = max(-(-x.shape[0] // block_n), 1)
            pruned = int(_np.asarray(out[3])[1])
            visited = n_ti * (n_ti + 1) // 2 - pruned
            realized = _roofline.pair_join_cost(
                x.shape[0], x.shape[1], k, block_n=block_n,
                tiles_visited=visited)
            sp.attrs.update(realized.attrs())
            sp.attrs["tiles_pruned"] = pruned
    return out


@_instrumented("kernel.verify_topk", _verify_cost)
def verify_topk(data: jax.Array, q: jax.Array, cand: jax.Array, k: int, *,
                force: str | None = None, **block_kw
                ) -> tuple[jax.Array, jax.Array]:
    """Fused VERIFY: exact distances on candidate ids + top-k answer.

    data (n,d) × q (B,d) × cand (B,Tc) → (d² (B,k) ascending, ids (B,k)).
    The kernel gathers candidate rows HBM→VMEM tile-by-tile and never
    materializes the (B,Tc,d) tensor; the ref oracle (and the k > 128
    regime, where the in-VMEM selection network does not apply) does.
    """
    mode = _mode(force)
    if mode == "ref" or k > 128:
        return ref.verify_topk(data, q, cand, k)
    return verify_topk_pallas(data, q, cand, k,
                              interpret=(mode == "interpret"), **block_kw)
