"""Hierarchical span tracer — where wall-clock time actually goes.

The paper argues about query cost with a model (Table 2, Eq. 7-10);
``WorkStats`` counts the model's units (distance computations).  This
module records the third leg: measured wall time, per pipeline stage,
as a tree of :class:`Span`s.

Design constraints (DESIGN.md §12):

  * ~zero cost disabled.  One module-level boolean guards everything;
    ``span()`` returns a shared no-op context manager without touching
    the collector, so instrumented hot paths pay one attribute load
    and one branch.  Engines keep their fully-jit pipelines when
    tracing is off — the traced stage-by-stage variants only run when
    someone asked for a trace.
  * safe around jit.  An asynchronously dispatched jax call returns
    before the device finishes; a span that closes without
    synchronizing would attribute device time to whichever span
    happens to block later.  ``block()`` calls ``block_until_ready``
    on its arguments **only while tracing** (no-op otherwise), and the
    kernel-dispatch instrumentation in ``repro.kernels.ops`` skips
    span creation entirely when any argument is an abstract tracer
    (i.e. the op is being traced *by jit*, not executed).
  * nestable across engines.  Spans form a tree via a per-tracer
    stack: the serve scheduler's flush span contains the streaming
    index's fan-out spans, which contain the fused pipeline's stage
    spans, which contain per-kernel spans carrying roofline attrs.

Usage::

    from repro.obs import trace

    with trace.trace() as tr:           # enables, collects, disables
        index.search(Q, k=10)
    trace.save(tr)  # or export.to_chrome_trace(tr.spans)
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Iterator

__all__ = ["Span", "Tracer", "Trace", "get_tracer", "enabled", "enable",
           "disable", "span", "add_span", "block", "concrete", "trace"]

#: the one flag every instrumented call site checks first (module
#: attribute load + truth test — the entire disabled-mode cost)
_ENABLED: bool = False


@dataclasses.dataclass
class Span:
    """One timed region.  ``parent`` indexes the tracer's span list
    (-1 for roots); ``attrs`` carries whatever the site recorded —
    kernel spans get modeled ``bytes``/``flops`` (see
    ``repro.obs.roofline``), serve spans get shapes and reasons."""

    name: str
    t0: float  # perf_counter seconds
    t1: float
    parent: int
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return max(self.t1 - self.t0, 0.0)

    @property
    def duration_us(self) -> float:
        return self.duration_s * 1e6


class _NullSpan:
    """Shared no-op context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Process-global span collector (one per process is the intended
    use; tests may instantiate their own).  Bounded: past ``max_spans``
    new spans are counted in ``dropped`` instead of stored, so a traced
    long-running server cannot grow without bound."""

    def __init__(self, max_spans: int = 200_000):
        self.max_spans = int(max_spans)
        self.spans: list[Span] = []
        self.dropped = 0
        self._stack: list[int] = []

    # -- recording -------------------------------------------------------

    @contextmanager
    def span(self, name: str, **attrs) -> Iterator[Span | None]:
        """Open a child span of whatever span is currently on the
        stack.  The span's end time is stamped at exit — call
        :func:`block` on async jax results inside, or the device work
        escapes the span."""
        if len(self.spans) >= self.max_spans:
            self.dropped += 1
            yield None
            return
        idx = len(self.spans)
        parent = self._stack[-1] if self._stack else -1
        s = Span(name, time.perf_counter(), 0.0, parent, attrs)
        self.spans.append(s)
        self._stack.append(idx)
        try:
            yield s
        finally:
            s.t1 = time.perf_counter()
            self._stack.pop()

    def add_span(self, name: str, t0: float, t1: float, **attrs) -> Span | None:
        """Record a span with explicit perf_counter endpoints (e.g. a
        request's queue wait, whose start predates the current span).
        Parented to the currently open span."""
        if len(self.spans) >= self.max_spans:
            self.dropped += 1
            return None
        parent = self._stack[-1] if self._stack else -1
        s = Span(name, float(t0), float(t1), parent, attrs)
        self.spans.append(s)
        return s

    # -- draining --------------------------------------------------------

    def drain(self) -> list[Span]:
        """Return collected spans and reset the collector."""
        out, self.spans = self.spans, []
        self._stack.clear()
        self.dropped = 0
        return out

    def roots(self) -> list[Span]:
        return [s for s in self.spans if s.parent == -1]


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def span(name: str, **attrs):
    """Module-level span helper: a real span while tracing, the shared
    no-op context manager otherwise."""
    if not _ENABLED:
        return _NULL_SPAN
    return _TRACER.span(name, **attrs)


def add_span(name: str, t0: float, t1: float, **attrs) -> Span | None:
    if not _ENABLED:
        return None
    return _TRACER.add_span(name, t0, t1, **attrs)


def concrete(*args) -> bool:
    """True when no argument is an abstract jax tracer — i.e. we are
    executing, not being traced by jit.  Span creation inside a jit
    trace would time the *trace*, not the computation."""
    try:
        from jax.core import Tracer as _JaxTracer
    except Exception:  # pragma: no cover - ancient jax
        return True
    return not any(isinstance(a, _JaxTracer) for a in args)


def block(*values):
    """``block_until_ready`` every jax array in ``values`` while
    tracing (no-op otherwise).  Returns the single value or the tuple,
    so call sites can wrap returns: ``return block(x)``."""
    if _ENABLED:
        for v in values:
            _block_one(v)
    return values[0] if len(values) == 1 else values


def _block_one(v) -> None:
    bur = getattr(v, "block_until_ready", None)
    if bur is not None:
        bur()
    elif isinstance(v, (tuple, list)):
        for item in v:
            _block_one(item)


@dataclasses.dataclass
class Trace:
    """The result of one ``with trace.trace()`` region."""

    spans: list[Span] = dataclasses.field(default_factory=list)
    dropped: int = 0

    def roots(self) -> list[Span]:
        return [s for s in self.spans if s.parent == -1]


@contextmanager
def trace() -> Iterator[Trace]:
    """Enable tracing for the body, then hand the collected spans back
    on the yielded :class:`Trace`.  Re-entrant uses nest: only the
    outermost exit disables tracing and drains the collector."""
    tr = Trace()
    was_enabled = _ENABLED
    mark = len(_TRACER.spans)
    enable()
    try:
        yield tr
    finally:
        if not was_enabled:
            disable()
            tr.spans = _TRACER.drain()
            tr.dropped = 0
        else:  # nested: take only the spans this region added, with
            # parent indices rebased onto the slice
            sliced = _TRACER.spans[mark:]
            tr.spans = [
                dataclasses.replace(
                    s, parent=(s.parent - mark if s.parent >= mark else -1))
                for s in sliced
            ]
            tr.dropped = _TRACER.dropped
