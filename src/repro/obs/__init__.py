"""repro.obs — structured tracing, roofline profiling, exporters.

The observability layer (DESIGN.md §12): hierarchical wall-clock spans
over every query engine (``obs.trace``), modeled bytes/FLOPs per
kernel dispatch with achieved-arithmetic-intensity placement
(``obs.roofline``), and Chrome-trace/Perfetto + flat-summary
exporters (``obs.export``).

Quickstart::

    from repro import obs

    with obs.tracing() as tr:
        index.search(Q, k=10)
    obs.save_chrome_trace("query_trace.json", tr)   # open in Perfetto
    print(obs.stage_summary(tr))                    # flat per-stage µs
"""
from . import export, roofline, trace
from .export import (coverage, save_chrome_trace, stage_summary,
                     to_chrome_trace, validate_chrome_trace)
from .roofline import DevicePeaks, KernelCost, achieved, device_kind
from .trace import (Span, Trace, Tracer, add_span, block, concrete,
                    disable, enable, enabled, get_tracer, span)
from .trace import trace as tracing

__all__ = [
    "tracing", "Span", "Trace", "Tracer", "get_tracer",
    "enabled", "enable", "disable", "span", "add_span", "block",
    "concrete", "export", "roofline", "trace", "KernelCost",
    "DevicePeaks", "achieved", "device_kind", "to_chrome_trace",
    "save_chrome_trace", "validate_chrome_trace", "stage_summary",
    "coverage",
]
