"""repro.obs — tracing, rooflines, metrics, and quality auditing.

The observability layer (DESIGN.md §12–§13): hierarchical wall-clock
spans over every query engine (``obs.trace``), modeled bytes/FLOPs per
kernel dispatch with achieved-arithmetic-intensity placement
(``obs.roofline``), Chrome-trace/Perfetto + flat-summary exporters
(``obs.export``), a process-global metrics registry with Prometheus
text exposition (``obs.metrics``), a shadow ground-truth quality
auditor — online recall@k / approximation ratio / Lemma-3 CI coverage
over hash-sampled live queries (``obs.quality``) — and a streaming
projection-drift monitor that raises a recalibrate signal
(``obs.drift``).

Quickstart::

    from repro import obs

    with obs.tracing() as tr:
        index.search(Q, k=10)
    obs.save_chrome_trace("query_trace.json", tr)   # open in Perfetto
    print(obs.stage_summary(tr))                    # flat per-stage µs

    auditor = obs.QualityAuditor.for_index(index, sample_fraction=0.05)
    res = index.search(q[None], k=10)
    auditor.maybe_sample(q, res.indices[0], res.distances[0])
    auditor.audit()
    print(auditor.report())                 # recall / ratio / coverage
    print(obs.get_registry().to_prometheus())
"""
from . import drift, export, metrics, quality, roofline, trace
from .drift import DriftMonitor, DriftReport
from .export import (coverage, save_chrome_trace, stage_summary,
                     to_chrome_trace, validate_chrome_trace)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .quality import QualityAuditor, QualityReport
from .roofline import DevicePeaks, KernelCost, achieved, device_kind
from .trace import (Span, Trace, Tracer, add_span, block, concrete,
                    disable, enable, enabled, get_tracer, span)
from .trace import trace as tracing

__all__ = [
    "tracing", "Span", "Trace", "Tracer", "get_tracer",
    "enabled", "enable", "disable", "span", "add_span", "block",
    "concrete", "export", "roofline", "trace", "KernelCost",
    "DevicePeaks", "achieved", "device_kind", "to_chrome_trace",
    "save_chrome_trace", "validate_chrome_trace", "stage_summary",
    "coverage", "metrics", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "get_registry", "quality", "QualityAuditor",
    "QualityReport", "drift", "DriftMonitor", "DriftReport",
]

# the tracer's ring-buffer drop counter, scrapeable alongside the
# quality/serve series (pull-time: reads the live tracer on collect)
get_registry().gauge(
    "trace_dropped_spans", "spans dropped by the tracer ring buffer"
).set_fn(lambda: float(get_tracer().dropped))
