"""Shadow ground-truth auditor: does the quality math hold on live traffic?

PM-LSH's headline claim is *accurate distance estimation* — Lemma 2's
χ² estimator with Lemma 3's tunable 1−2α confidence interval — and the
rest of the stack (Eq. 9 select seeds, Eq. 10 candidate budgets, the
quant rerank tiers) inherits its calibration from that model.  Nothing
before this module *checked* the model against what the running system
actually serves.  The auditor closes that loop:

  * **deterministic sampling.**  ``sampled(query)`` hashes the query
    bytes (keyed blake2) against ``sample_fraction`` — the same query
    always makes the same decision, so an audit is replayable offline
    and two processes sampling the same trace agree.  No RNG state.
  * **shadow ground truth, off the hot path.**  A sampled query is
    *enqueued* with the answer it was served; ``audit()`` later runs
    the exact brute-force kNN over the live rows and scores the served
    answer against it.  The hot path pays one hash and one small copy.
  * **online quality estimates.**  Running recall@k, realized
    approximation ratio (served/exact distance, positionwise — the
    paper's Eq. 12 overall ratio), and **measured CI coverage**: the
    fraction of (query, true-neighbor) pairs whose projected distance
    falls inside Lemma 3's interval ``[r·√(χ²_{1−α}(m)),
    r·√(χ²_α(m))]``.  Under the χ²(m) model that fraction IS 1−2α;
    the gap to the nominal value from :class:`PMLSHParams` is the
    calibration error the drift monitor (``obs.drift``) and ROADMAP
    item 2's adaptive termination need as input.

Every estimate is published through the ``repro.obs.metrics``
registry (gauges ``quality_recall`` / ``quality_ratio`` /
``quality_ci_coverage`` / ``quality_calibration_error``, counters
``quality_sampled_total`` / ``quality_audited_total``), so one
Prometheus endpoint answers "is the index still accurate".

Accounting identity (the check_api quality gate asserts it):
``audited == sampled − pending`` — every sampled query is either
scored or still in the queue, never silently dropped (a full queue
refuses the *sample*, so the identity survives overload).

Usage::

    auditor = QualityAuditor.for_index(index, sample_fraction=0.05)
    res = index.search(q[None], k=10)
    auditor.maybe_sample(q, res.indices[0], res.distances[0])
    auditor.audit()                  # brute-force scoring, off-path
    rep = auditor.report()           # recall / ratio / coverage / alarm
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from collections import deque
from typing import Callable

import numpy as np

__all__ = ["QualityAuditor", "QualityReport", "ci_coverage",
           "sample_decision"]


def sample_decision(query_bytes: bytes, fraction: float,
                    seed: int = 0) -> bool:
    """Deterministic, replayable coin flip: keyed-hash the query bytes
    into [0, 1) and compare against ``fraction``.  The same (query,
    seed) always lands the same side, independent of call order."""
    if fraction <= 0.0:
        return False
    if fraction >= 1.0:
        return True
    h = hashlib.blake2b(query_bytes, digest_size=8,
                        key=struct.pack("<q", seed)).digest()
    return int.from_bytes(h, "little") < fraction * 2.0 ** 64


def ci_coverage(exact_dists: np.ndarray, projected_dists: np.ndarray,
                m: int, alpha: float) -> tuple[int, int]:
    """Lemma 3 coverage count: of the (query, neighbor) pairs with true
    distance r > 0, how many projected distances r' landed inside
    ``[r·√(χ²_{1−α}(m)), r·√(χ²_α(m))]``?  Returns (inside, total).
    Under the Lemma-1 model r'²/r² ~ χ²(m), so inside/total → 1−2α."""
    from repro.core.estimator import chi2_upper_quantile

    r = np.asarray(exact_dists, np.float64).reshape(-1)
    rp = np.asarray(projected_dists, np.float64).reshape(-1)
    ok = r > 0
    r, rp = r[ok], rp[ok]
    if r.size == 0:
        return 0, 0
    lo = np.sqrt(chi2_upper_quantile(1.0 - alpha, m))
    hi = np.sqrt(chi2_upper_quantile(alpha, m))
    ratio = rp / r
    inside = int(np.sum((ratio >= lo) & (ratio <= hi)))
    return inside, int(r.size)


@dataclasses.dataclass(frozen=True)
class QualityReport:
    """Frozen view of the auditor's online estimates."""

    sampled: int  # queries the hash admitted
    audited: int  # queries scored against brute force
    pending: int  # sampled, not yet scored (in-flight)
    recall: float  # mean recall@k over audited queries
    ratio: float  # mean realized approximation ratio (Eq. 12 form)
    ci_coverage: float  # measured Lemma-3 coverage over neighbor pairs
    nominal_coverage: float  # 1 − 2α from PMLSHParams
    coverage_pairs: int  # (query, neighbor) pairs behind ci_coverage
    alpha: float

    @property
    def calibration_error(self) -> float:
        """Nominal − measured coverage: positive = the live data is
        UNDER-covered vs the χ²(m) model (recalibration signal)."""
        return self.nominal_coverage - self.ci_coverage

    def alarming(self, tolerance: float = 0.05, min_pairs: int = 50) -> bool:
        """True when measured coverage trails nominal by more than
        ``tolerance`` with at least ``min_pairs`` pairs observed."""
        return (self.coverage_pairs >= min_pairs
                and self.calibration_error > tolerance)


class QualityAuditor:
    """Online recall / ratio / CI-coverage auditing over live queries.

    Args:
      get_rows: callable returning ``(ids (n,) int64, rows (n, d))`` —
        the CURRENT live datastore (called at audit time, so mutations
        between sampling and auditing score against fresh truth).
      family: projection family (``project(q)``) for the coverage
        audit; None disables coverage (recall/ratio still run).
      m / alpha: the χ² model order and CI tail mass (typically
        ``params.m`` / ``params.alpha1`` from the build-time Eq. 10
        solve — nominal coverage is 1 − 2α).
      sample_fraction / seed: the deterministic hash sampler's knobs.
      max_pending: audit-queue bound; a full queue REFUSES new samples
        (counted in ``overflowed``) so the shadow copy of a overloaded
        server stays bounded.
      registry: metrics registry to publish through (default global).
    """

    def __init__(self, get_rows: Callable[[], tuple[np.ndarray, np.ndarray]],
                 *, family=None, m: int = 15, alpha: float | None = None,
                 sample_fraction: float = 0.01, seed: int = 0,
                 max_pending: int = 256, registry=None):
        import math

        from . import metrics as _metrics

        self.get_rows = get_rows
        self.family = family
        self.m = int(m)
        self.alpha = float(alpha if alpha is not None else 1.0 / math.e)
        self.sample_fraction = float(sample_fraction)
        self.seed = int(seed)
        self.max_pending = int(max_pending)
        self._pending: deque = deque()
        self.sampled = 0
        self.audited = 0
        self.overflowed = 0  # samples refused by a full queue
        self._recall_sum = 0.0
        self._ratio_sum = 0.0
        self._ratio_n = 0
        self._cov_inside = 0
        self._cov_total = 0
        reg = registry if registry is not None else _metrics.get_registry()
        self._g_recall = reg.gauge("quality_recall",
                                   "audited recall@k (running mean)")
        self._g_ratio = reg.gauge(
            "quality_ratio", "realized approximation ratio (running mean)")
        self._g_cov = reg.gauge("quality_ci_coverage",
                                "measured Lemma-3 CI coverage")
        self._g_cal = reg.gauge(
            "quality_calibration_error",
            "nominal (1-2a) minus measured CI coverage")
        self._c_sampled = reg.counter("quality_sampled_total",
                                      "queries admitted by the hash sampler")
        self._c_audited = reg.counter("quality_audited_total",
                                      "queries scored against brute force")
        self._g_cal.set(0.0)
        self._g_cov.set(self.nominal_coverage)

    @classmethod
    def for_index(cls, index, *, sample_fraction: float = 0.01,
                  seed: int = 0, alpha: float | None = None, **kw
                  ) -> "QualityAuditor":
        """Build an auditor wired to a facade backend: live rows from
        the index (streaming ``live_ids``/``get_vectors`` or static
        ``data``), the projection family and χ² order from the
        build-time config, α from the cached Eq. 10 solve."""
        from repro.core.estimator import solve_parameters
        from repro.core.hashing import ProjectionFamily

        cfg = getattr(index, "config", None)
        impl = getattr(index, "impl", None)
        family = getattr(impl, "family", None)
        params = getattr(impl, "params", None)
        if params is None and cfg is not None:
            params = solve_parameters(cfg.c, m=cfg.m)
        m = params.m if params is not None else getattr(cfg, "m", 15)
        if family is None and cfg is not None:
            family = ProjectionFamily.create(index.d, m, seed=cfg.seed)

        def get_rows():
            live_ids = getattr(index, "live_ids", None)
            get_vectors = getattr(index, "get_vectors", None)
            if callable(live_ids) and callable(get_vectors):
                ids = np.asarray(live_ids(), np.int64)
                return ids, get_vectors(ids)
            rows = np.asarray(index.data, np.float32)
            return np.arange(rows.shape[0], dtype=np.int64), rows

        if alpha is None and params is not None:
            alpha = params.alpha1
        return cls(get_rows, family=family, m=m, alpha=alpha,
                   sample_fraction=sample_fraction, seed=seed, **kw)

    @property
    def nominal_coverage(self) -> float:
        return 1.0 - 2.0 * self.alpha

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- hot path ---------------------------------------------------------

    def sampled_query(self, query: np.ndarray) -> bool:
        """The deterministic sampling decision alone (replayable)."""
        q = np.ascontiguousarray(query, np.float32)
        return sample_decision(q.tobytes(), self.sample_fraction, self.seed)

    def maybe_sample(self, query, indices, distances) -> bool:
        """Hash-sample one served answer into the audit queue.

        ``indices`` / ``distances`` are the (k,) served answer row
        (global ids, original-space distances).  Returns True when the
        query was enqueued.  Cost on the miss path: one hash."""
        q = np.ascontiguousarray(np.asarray(query, np.float32).reshape(-1))
        if not sample_decision(q.tobytes(), self.sample_fraction, self.seed):
            return False
        if len(self._pending) >= self.max_pending:
            self.overflowed += 1
            return False
        self.sampled += 1
        self._c_sampled.inc()
        self._pending.append((q.copy(),
                              np.asarray(indices, np.int64).reshape(-1).copy(),
                              np.asarray(distances,
                                         np.float32).reshape(-1).copy()))
        return True

    # -- off the hot path -------------------------------------------------

    def audit(self, max_items: int | None = None) -> int:
        """Score up to ``max_items`` pending samples against exact
        brute-force kNN over the current live rows; returns how many
        were audited.  Call from idle time (the serve scheduler's
        ``pump`` does) or at end-of-trace."""
        if not self._pending:
            return 0
        ids, rows = self.get_rows()
        ids = np.asarray(ids, np.int64).reshape(-1)
        rows = np.asarray(rows, np.float32)
        done = 0
        proj_rows = None
        while self._pending and (max_items is None or done < max_items):
            q, served_ids, served_dd = self._pending.popleft()
            done += 1
            self.audited += 1
            self._c_audited.inc()
            if rows.shape[0] == 0:
                continue
            k = int(np.sum(served_ids >= 0)) or served_ids.size
            k = min(k, rows.shape[0])
            dd = np.linalg.norm(rows - q[None], axis=-1)
            part = np.argpartition(dd, k - 1)[:k]
            order = part[np.argsort(dd[part], kind="stable")]
            exact_ids = ids[order]
            exact_dd = dd[order]
            got = set(int(i) for i in served_ids if i >= 0)
            self._recall_sum += len(got & set(int(i) for i in exact_ids)) / k
            # realized ratio, positionwise over the valid served prefix
            sv = np.sort(served_dd[np.isfinite(served_dd)])[:k]
            if sv.size:
                ex = exact_dd[: sv.size]
                self._ratio_sum += float(
                    np.mean(sv / np.maximum(ex, 1e-12)))
                self._ratio_n += 1
            if self.family is not None:
                if proj_rows is None:
                    proj_rows = np.asarray(self.family.project(rows))
                qp = np.asarray(self.family.project(q[None]))[0]
                rp = np.linalg.norm(proj_rows[order] - qp[None], axis=-1)
                inside, total = ci_coverage(exact_dd, rp, self.m, self.alpha)
                self._cov_inside += inside
                self._cov_total += total
        self._publish()
        return done

    def _publish(self) -> None:
        rep = self.report()
        self._g_recall.set(rep.recall)
        self._g_ratio.set(rep.ratio)
        self._g_cov.set(rep.ci_coverage)
        self._g_cal.set(rep.calibration_error)

    def report(self) -> QualityReport:
        audited = max(self.audited, 1)
        cov = (self._cov_inside / self._cov_total if self._cov_total
               else self.nominal_coverage)
        return QualityReport(
            sampled=self.sampled, audited=self.audited,
            pending=len(self._pending),
            recall=self._recall_sum / audited,
            ratio=self._ratio_sum / max(self._ratio_n, 1),
            ci_coverage=cov, nominal_coverage=self.nominal_coverage,
            coverage_pairs=self._cov_total, alpha=self.alpha,
        )
