"""Streaming projection-drift monitor: does build-time calibration still fit?

Eq. 9/10 calibration (the select kernel's τ₀ seed, the Eq. 10 candidate
budget, the quant codebook ranges) is solved ONCE from the distribution
the index was built on.  A streaming index keeps ingesting; when the
live distribution walks away from the build-time one, the χ²(m) model's
constants quietly stop matching reality — recall erodes with no error
anywhere (Jafari et al., arXiv 2006.11285, measure exactly this).  The
monitor watches two cheap projection-space signals and raises a
"recalibrate" flag when either moves:

  * **projected-coordinate moments.**  A Welford accumulator over the
    baseline (build/first-N) rows' projected coordinates, and an EWMA
    over live inserts.  Drift statistics: the standardized mean shift
    ``|μ_live − μ_base| / σ_base`` and the log variance ratio
    ``|log(σ²_live / σ²_base)|``.  Mean-zero Gaussian projections make
    both ≈0 for stationary data regardless of the raw data's scale.
  * **survivor-count occupancy.**  The radius-select kernel reports
    per-query survivor counts (realized T, PR 8's
    ``WorkStats.candidates_selected``).  Their histogram over bins of
    the T budget is the live image of the rung-ladder occupancy the
    kernel's τ ladder was sized for; total-variation distance between
    the baseline and live occupancy histograms catches distribution
    shifts that leave the first two moments alone.

All three scores publish as gauges (``drift_mean_shift``,
``drift_var_ratio``, ``drift_occupancy_tv``) plus the binary
``drift_recalibrate`` flag, so the signal is scrapeable alongside the
quality gauges from :mod:`repro.obs.quality`.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["DriftMonitor", "DriftReport"]


class _Welford:
    """Numerically stable running mean/variance (scalar stream)."""

    __slots__ = ("n", "mean", "m2")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0

    def add_batch(self, x: np.ndarray) -> None:
        x = np.asarray(x, np.float64).reshape(-1)
        if x.size == 0:
            return
        n_b, mean_b = x.size, float(x.mean())
        m2_b = float(((x - mean_b) ** 2).sum())
        if self.n == 0:
            self.n, self.mean, self.m2 = n_b, mean_b, m2_b
            return
        delta = mean_b - self.mean
        tot = self.n + n_b
        self.m2 += m2_b + delta * delta * self.n * n_b / tot
        self.mean += delta * n_b / tot
        self.n = tot

    @property
    def var(self) -> float:
        return self.m2 / self.n if self.n > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Drift statistics at one point in time (all ≈0 when stationary)."""

    baseline_rows: int
    live_rows: int
    mean_shift: float  # |EWMA(live mean) − base mean| / base std
    var_ratio: float  # |log(EWMA(live var) / base var)|
    occupancy_tv: float  # TV distance, live vs baseline survivor histogram
    recalibrate: bool

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class DriftMonitor:
    """Track projection-space statistics across inserts/compactions.

    Args:
      family: projection family; ``observe_rows`` projects through it.
        None means callers pass already-projected coordinates.
      baseline_rows: first N observed rows freeze the baseline; later
        rows feed the live EWMA.  (Compaction does not reset the
        baseline — drift is measured against *build-time* calibration,
        which is what Eq. 9/10 solved against.)
      ewma_alpha: per-batch smoothing for the live moments.
      occupancy_bins: survivor-count histogram bins over [0, T].
      mean_tol / var_tol / tv_tol: per-signal recalibrate thresholds.
    """

    def __init__(self, family=None, *, baseline_rows: int = 256,
                 ewma_alpha: float = 0.2, occupancy_bins: int = 8,
                 mean_tol: float = 0.5, var_tol: float = 0.69,
                 tv_tol: float = 0.35, registry=None):
        from . import metrics as _metrics

        self.family = family
        self.baseline_rows = int(baseline_rows)
        self.ewma_alpha = float(ewma_alpha)
        self.occupancy_bins = int(occupancy_bins)
        self.mean_tol = float(mean_tol)
        self.var_tol = float(var_tol)
        self.tv_tol = float(tv_tol)
        self._base = _Welford()
        self._live_rows = 0
        self._ewma_mean: float | None = None
        self._ewma_var: float | None = None
        self._occ_base = np.zeros(self.occupancy_bins, np.float64)
        self._occ_live = np.zeros(self.occupancy_bins, np.float64)
        self._occ_live_n = 0
        reg = registry if registry is not None else _metrics.get_registry()
        self._g_mean = reg.gauge("drift_mean_shift",
                                 "standardized projected-mean shift vs build")
        self._g_var = reg.gauge("drift_var_ratio",
                                "abs log projected-variance ratio vs build")
        self._g_tv = reg.gauge(
            "drift_occupancy_tv",
            "TV distance of survivor-count occupancy vs build")
        self._g_flag = reg.gauge("drift_recalibrate",
                                 "1 when drift exceeds tolerance")
        for g in (self._g_mean, self._g_var, self._g_tv, self._g_flag):
            g.set(0.0)

    # -- data-side signal -------------------------------------------------

    def observe_rows(self, rows: np.ndarray) -> None:
        """Feed inserted rows (n, d); projected through ``family`` when
        one is set, else treated as projected coordinates already."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        if rows.shape[0] == 0:
            return
        proj = (np.asarray(self.family.project(rows))
                if self.family is not None else rows)
        coords = np.asarray(proj, np.float64).reshape(-1)
        if self._base.n < self.baseline_rows * max(proj.shape[-1], 1):
            self._base.add_batch(coords)
            return
        self._live_rows += rows.shape[0]
        m, v = float(coords.mean()), float(coords.var())
        a = self.ewma_alpha
        self._ewma_mean = m if self._ewma_mean is None else (
            (1 - a) * self._ewma_mean + a * m)
        self._ewma_var = v if self._ewma_var is None else (
            (1 - a) * self._ewma_var + a * v)
        self._publish()

    # -- query-side signal ------------------------------------------------

    def observe_survivors(self, counts: np.ndarray, budget: int) -> None:
        """Feed per-query survivor counts from the radius-select kernel
        together with the T budget they were selected under."""
        counts = np.asarray(counts, np.float64).reshape(-1)
        if counts.size == 0 or budget <= 0:
            return
        frac = np.clip(counts / float(budget), 0.0, 1.0 - 1e-9)
        hist = np.bincount((frac * self.occupancy_bins).astype(np.int64),
                           minlength=self.occupancy_bins).astype(np.float64)
        if self._occ_base.sum() < self.baseline_rows:
            self._occ_base += hist
            return
        self._occ_live += hist
        self._occ_live_n += counts.size
        self._publish()

    @staticmethod
    def _tv(p: np.ndarray, q: np.ndarray) -> float:
        sp, sq = p.sum(), q.sum()
        if sp == 0 or sq == 0:
            return 0.0
        return 0.5 * float(np.abs(p / sp - q / sq).sum())

    # -- reporting --------------------------------------------------------

    def report(self) -> DriftReport:
        base_std = math.sqrt(max(self._base.var, 1e-24))
        mean_shift = (abs(self._ewma_mean - self._base.mean) / base_std
                      if self._ewma_mean is not None and self._base.n else 0.0)
        var_ratio = (abs(math.log(max(self._ewma_var, 1e-24)
                                  / max(self._base.var, 1e-24)))
                     if self._ewma_var is not None and self._base.n else 0.0)
        tv = (self._tv(self._occ_base, self._occ_live)
              if self._occ_live_n >= self.occupancy_bins else 0.0)
        recal = (mean_shift > self.mean_tol or var_ratio > self.var_tol
                 or tv > self.tv_tol)
        return DriftReport(
            baseline_rows=self._base.n, live_rows=self._live_rows,
            mean_shift=mean_shift, var_ratio=var_ratio, occupancy_tv=tv,
            recalibrate=recal,
        )

    def _publish(self) -> None:
        rep = self.report()
        self._g_mean.set(rep.mean_shift)
        self._g_var.set(rep.var_ratio)
        self._g_tv.set(rep.occupancy_tv)
        self._g_flag.set(1.0 if rep.recalibrate else 0.0)
