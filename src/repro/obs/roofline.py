"""Roofline models: modeled bytes + FLOPs per kernel dispatch.

Extends ``benchmarks/cost_model.query_traffic_model``'s per-stage HBM
byte accounting down to the individual ``repro.kernels.ops`` dispatch:
every kernel span records the bytes the op must move and the FLOPs it
must execute for its argument shapes, so a trace pairs each measured
duration with its model and answers *memory-bound or compute-bound,
and at what fraction of peak* (DESIGN.md §12).

Conventions:

  * bytes are the minimal one-pass traffic of the op at float32 (code
    arrays at their stored width) — reads of every input once, writes
    of every output once.  Kernels that re-read (the radius-select
    ladder) model their pass count explicitly.
  * FLOPs count multiply and add separately (one MAC = 2 FLOPs),
    compares/selects count 1 — the usual roofline convention.
  * arithmetic intensity AI = flops / bytes.  Against a device's
    (peak_flops, peak_bw) the ridge point is peak_flops / peak_bw;
    AI below the ridge → the op is memory-bound, its attainable
    ceiling is AI · peak_bw; above → compute-bound at peak_flops.

Peaks default to rough public numbers per ``jax.default_backend()``
kind and exist to *classify* (the bound and a fraction-of-peak
estimate), not to certify — override via :func:`set_peaks` for a real
machine.
"""
from __future__ import annotations

import dataclasses

__all__ = ["KernelCost", "DevicePeaks", "device_kind", "get_peaks",
           "set_peaks", "pairwise_sq_dist_cost", "project_dist_cost",
           "adc_dist_cost", "topk_cost", "radius_select_cost",
           "verify_topk_cost", "pair_join_cost", "achieved"]

F32 = 4


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Modeled single-execution cost of one kernel dispatch."""

    bytes: int
    flops: int

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (FLOPs per byte moved)."""
        return self.flops / max(self.bytes, 1)

    def attrs(self) -> dict:
        """The span-attribute form kernel instrumentation records."""
        return {"bytes": int(self.bytes), "flops": int(self.flops),
                "intensity": round(self.intensity, 4)}


@dataclasses.dataclass(frozen=True)
class DevicePeaks:
    """Nominal (peak FLOP/s, peak bytes/s) for classification."""

    kind: str
    peak_flops: float
    peak_bw: float

    @property
    def ridge(self) -> float:
        """AI at which the roofline transitions memory→compute bound."""
        return self.peak_flops / self.peak_bw


#: rough public-spec numbers — enough to place an op on the roofline;
#: override with set_peaks() when certifying a specific machine
_DEFAULT_PEAKS = {
    # ~8-core AVX2 server slice: 8c · 2.5GHz · 16 f32 FLOP/cycle; DDR4
    "cpu": DevicePeaks("cpu", 3.2e11, 4.0e10),
    # A100-class accelerator
    "gpu": DevicePeaks("gpu", 1.95e13, 1.55e12),
    # TPU v4-class MXU + HBM2e
    "tpu": DevicePeaks("tpu", 2.75e14, 1.2e12),
}
_PEAKS_OVERRIDE: DevicePeaks | None = None


def device_kind() -> str:
    """The jax backend kind ("cpu" | "gpu" | "tpu"), "cpu" if jax is
    unimportable (pure-numpy contexts)."""
    try:
        import jax

        return str(jax.default_backend())
    except Exception:  # pragma: no cover
        return "cpu"


def get_peaks(kind: str | None = None) -> DevicePeaks:
    if _PEAKS_OVERRIDE is not None:
        return _PEAKS_OVERRIDE
    kind = kind or device_kind()
    return _DEFAULT_PEAKS.get(kind, _DEFAULT_PEAKS["cpu"])


def set_peaks(peaks: DevicePeaks | None) -> None:
    """Pin measured peaks for this process (None restores defaults)."""
    global _PEAKS_OVERRIDE
    _PEAKS_OVERRIDE = peaks


# ---------------------------------------------------------------------------
# per-kernel models (shapes as the ops-layer sees them)
# ---------------------------------------------------------------------------


def pairwise_sq_dist_cost(B: int, N: int, d: int) -> KernelCost:
    """ESTIMATE: (B,d)×(N,d)→(B,N).  One read of each input, one write
    of the output; 2·B·N·d MACs-worth of FLOPs (norm trick or direct
    difference cost the same to leading order)."""
    return KernelCost(bytes=(B * d + N * d + B * N) * F32,
                      flops=2 * B * N * d + 2 * B * N)


def project_dist_cost(N: int, d: int, m: int, B: int) -> KernelCost:
    """Fused project+distance: x (N,d) @ a (d,m), then (B,m)×(N,m)."""
    proj = KernelCost(bytes=(N * d + d * m) * F32, flops=2 * N * d * m)
    dist = pairwise_sq_dist_cost(B, N, m)
    return KernelCost(bytes=proj.bytes + dist.bytes,
                      flops=proj.flops + dist.flops)


def adc_dist_cost(B: int, N: int, S: int, V: int,
                  code_bytes: int = 1) -> KernelCost:
    """ADC rerank: codes (N,S) or (B,N,S) at 1 byte/slot + LUTs
    (B,S,V) f32 read once; one gather+add per (b, n, s)."""
    return KernelCost(bytes=B * N * S * code_bytes + B * S * V * F32
                      + B * N * F32,
                      flops=2 * B * N * S)


def topk_cost(B: int, N: int, k: int) -> KernelCost:
    """Selection-network top-k: one read of (B,N); ~N·k compares/row."""
    return KernelCost(bytes=(B * N + 2 * B * k) * F32, flops=B * N * k)


def radius_select_cost(B: int, N: int, T_pad: int,
                       passes: int = 16) -> KernelCost:
    """SELECT: the threshold ladder re-reads the (B,N) row once per
    counting pass (ladder + bisection + compaction ≈ ``passes`` —
    the same constant ``cost_model.query_traffic_model`` uses), then
    writes the compacted (B, T_pad) values + indices."""
    return KernelCost(bytes=passes * B * N * F32 + 2 * B * T_pad * F32,
                      flops=passes * B * N)


def verify_topk_cost(B: int, Tc: int, d: int, k: int) -> KernelCost:
    """Gather-free VERIFY: each candidate row DMA'd HBM→VMEM exactly
    once (B·Tc·d reads), queries once, (B,k)·2 answer writes; exact
    distances are 2·B·Tc·d FLOPs plus the streaming top-k compares."""
    return KernelCost(bytes=(B * Tc * d + B * d + 4 * B * k) * F32,
                      flops=2 * B * Tc * d + B * Tc * k)


def pair_join_cost(n: int, d: int, k: int, block_n: int = 128,
                   tiles_visited: int | None = None) -> KernelCost:
    """CP JOIN: band-major sweep over the upper-triangular tile space.
    Each *visited* tile DMAs two (block_n, d) row blocks and verifies
    block_n² pairs; ``tiles_visited`` defaults to the full triangle
    (the a-priori model — pruning is data-dependent, so post-hoc
    callers pass the kernel's realized ``tiles_pruned`` subtracted)."""
    n_ti = max(-(-n // block_n), 1)
    total_tiles = n_ti * (n_ti + 1) // 2
    tiles = total_tiles if tiles_visited is None else max(tiles_visited, 0)
    return KernelCost(
        bytes=tiles * 2 * block_n * d * F32 + 4 * k * F32,
        flops=tiles * (2 * block_n * block_n * d + block_n * block_n * k))


def shard_exchange_cost(P: int, B: int, k_l: int,
                        rounds: int = 32) -> KernelCost:
    """Sharded-ANN THRESHOLD EXCHANGE: the counts-only bisection.  Each
    of the ``rounds`` rungs psums one (B,) int32 survivor count per
    shard — ``rounds·P·B`` int32 on the wire, zero candidate payload.
    (The k_l argument is carried so callers can log the companion merge
    volume next to it; it does not enter this cost.)  FLOPs are the
    per-rung compare+reduce over nothing the model sees — counted as
    the P·B adds of the reduction tree."""
    del k_l
    return KernelCost(bytes=rounds * P * B * 4,
                      flops=rounds * P * B)


def shard_merge_cost(P: int, B: int, k_l: int) -> KernelCost:
    """All-gather-of-k MERGE: each shard contributes (B, k_l) float32
    distances + int32 ids; the replicated pool is P·B·k_l·8 bytes, the
    final selection P·B·k_l·k_l-ish compares (modeled linear — top_k
    over an L-pool is O(L log k), noise either way)."""
    return KernelCost(bytes=P * B * k_l * (F32 + 4),
                      flops=P * B * k_l)


def shard_ring_cost(P: int, nl: int, d: int, k: int) -> KernelCost:
    """One CP ring hop: every shard ppermutes its (nl, d) row block,
    (nl,) norms + keys, (nl,) ids to its neighbor, and the round's ub
    register refresh all-gathers each shard's (k,) running best."""
    return KernelCost(
        bytes=P * (nl * d * F32 + 3 * nl * F32 + k * F32),
        flops=P * nl * d)


# ---------------------------------------------------------------------------
# achieved performance: model + measured time → roofline placement
# ---------------------------------------------------------------------------


def achieved(cost: KernelCost, seconds: float,
             peaks: DevicePeaks | None = None) -> dict:
    """Place one measured execution on the roofline.

    Returns the span-attribute dict the exporter merges into kernel
    spans: achieved GFLOP/s and GB/s, the model's arithmetic
    intensity, the bound classification against ``peaks`` (memory if
    AI < ridge else compute) and the fraction of the *attainable*
    ceiling (min(peak_flops, AI·peak_bw)) the execution reached."""
    peaks = peaks or get_peaks()
    t = max(float(seconds), 1e-12)
    gflops = cost.flops / t / 1e9
    gbps = cost.bytes / t / 1e9
    ai = cost.intensity
    ceiling = min(peaks.peak_flops, ai * peaks.peak_bw)
    return {
        "achieved_gflops": round(gflops, 3),
        "achieved_gbps": round(gbps, 3),
        "intensity": round(ai, 4),
        "ridge": round(peaks.ridge, 4),
        "bound": "memory" if ai < peaks.ridge else "compute",
        "fraction_of_peak": round(cost.flops / t / max(ceiling, 1.0), 6),
        "device_kind": peaks.kind,
    }
