"""Trace exporters: Chrome-trace/Perfetto JSON + flat stage summaries.

Two consumers (DESIGN.md §12):

  * a human opens the Chrome-trace JSON in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing`` and reads the
    span tree on a timeline — each kernel span's ``args`` carries its
    roofline placement (modeled bytes/FLOPs, achieved GB/s and
    GFLOP/s, memory- vs compute-bound);
  * the benchmark harness embeds :func:`stage_summary`'s flat
    per-stage aggregate into ``BENCH_<module>.json`` via
    ``benchmarks.common.publish_summary``, so the perf trajectory
    records *where* time went, not just end-to-end p50s.

The Chrome-trace format used is the JSON object form: a top-level
``traceEvents`` list of complete ("ph": "X") events with microsecond
``ts``/``dur`` — the stable subset every trace viewer accepts.
"""
from __future__ import annotations

import json
import math
from typing import Iterable, Sequence

from . import roofline
from .trace import Span, Trace

__all__ = ["to_chrome_trace", "save_chrome_trace", "validate_chrome_trace",
           "stage_summary", "coverage"]


def _spans_of(spans) -> list[Span]:
    if isinstance(spans, Trace):
        return spans.spans
    return list(spans)


def _sanitize(value):
    """JSON-safe attr values (numpy scalars → python, inf → str)."""
    if hasattr(value, "item"):
        value = value.item()
    if isinstance(value, float) and not math.isfinite(value):
        return repr(value)
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    if isinstance(value, dict):
        return {str(k): _sanitize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_sanitize(v) for v in value]
    return str(value)


def to_chrome_trace(spans, *, pid: int = 1, tid: int = 1,
                    process_name: str = "repro",
                    peaks: roofline.DevicePeaks | None = None) -> dict:
    """Render spans as a Chrome-trace JSON object.

    Every span becomes one complete event; spans whose attrs carry
    modeled ``bytes``+``flops`` (the kernel spans recorded by
    ``repro.kernels.ops``) additionally get their roofline placement
    (:func:`repro.obs.roofline.achieved`) merged into ``args``.
    Timestamps are rebased so the earliest span starts at ts=0.
    """
    spans = _spans_of(spans)
    t_base = min((s.t0 for s in spans), default=0.0)
    events = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": tid,
        "args": {"name": process_name},
    }]
    for s in spans:
        args = {k: _sanitize(v) for k, v in s.attrs.items()}
        if "bytes" in s.attrs and "flops" in s.attrs and s.duration_s > 0:
            cost = roofline.KernelCost(int(s.attrs["bytes"]),
                                       int(s.attrs["flops"]))
            args.update(_sanitize(
                roofline.achieved(cost, s.duration_s, peaks)))
        events.append({
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ph": "X",
            "ts": round((s.t0 - t_base) * 1e6, 3),
            "dur": round(s.duration_us, 3),
            "pid": pid,
            "tid": tid,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def save_chrome_trace(path: str, spans, **kw) -> str:
    """Write :func:`to_chrome_trace` output to ``path``; returns it."""
    obj = to_chrome_trace(spans, **kw)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
        f.write("\n")
    return path


def validate_chrome_trace(obj: dict) -> None:
    """Raise ``ValueError`` unless ``obj`` is a structurally valid
    Chrome-trace JSON object (the subset this exporter emits): a
    ``traceEvents`` list whose complete events carry string names,
    known phases, and non-negative numeric ``ts``/``dur``."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("missing top-level traceEvents")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for req in ("name", "ph", "pid", "tid"):
            if req not in ev:
                raise ValueError(f"event {i} missing {req!r}")
        if not isinstance(ev["name"], str):
            raise ValueError(f"event {i} name is not a string")
        if ev["ph"] not in ("X", "B", "E", "M", "i", "C"):
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if ev["ph"] == "X":
            for fld in ("ts", "dur"):
                v = ev.get(fld)
                if not isinstance(v, (int, float)) or v < 0 \
                        or not math.isfinite(v):
                    raise ValueError(f"event {i} bad {fld}: {v!r}")
        if "args" in ev:
            json.dumps(ev["args"])  # must be serializable


def coverage(spans) -> float:
    """Fraction of root-span wall time covered by direct children —
    the "did the spans account for the measured time" check (the
    acceptance bar is ≥0.95 on the traced pipelines).  A leaf root is
    its own measurement and counts as fully covered; returns 1.0 for
    an empty trace."""
    spans = _spans_of(spans)
    root_total = child_total = 0.0
    by_parent: dict[int, float] = {}
    for i, s in enumerate(spans):
        if s.parent >= 0:
            by_parent[s.parent] = by_parent.get(s.parent, 0.0) + s.duration_s
    for i, s in enumerate(spans):
        if s.parent == -1:
            root_total += s.duration_s
            covered = by_parent.get(i)
            child_total += s.duration_s if covered is None \
                else min(covered, s.duration_s)
    if root_total <= 0.0:
        return 1.0
    return child_total / root_total


def stage_summary(spans, *, peaks: roofline.DevicePeaks | None = None) -> dict:
    """Flat per-stage aggregate for BENCH embedding.

    Groups spans by name; per stage: call count, total/mean µs, and —
    when the stage's spans carry roofline models — summed bytes/FLOPs,
    model arithmetic intensity, achieved GB/s / GFLOP/s over the
    stage's total time and the bound classification.  The envelope
    records total root wall time, span count, and :func:`coverage`.
    """
    spans = _spans_of(spans)
    stages: dict[str, dict] = {}
    for s in spans:
        st = stages.setdefault(s.name, {"count": 0, "total_us": 0.0,
                                        "bytes": 0, "flops": 0})
        st["count"] += 1
        st["total_us"] += s.duration_us
        if "bytes" in s.attrs and "flops" in s.attrs:
            st["bytes"] += int(s.attrs["bytes"])
            st["flops"] += int(s.attrs["flops"])
    for name, st in stages.items():
        st["total_us"] = round(st["total_us"], 1)
        st["mean_us"] = round(st["total_us"] / max(st["count"], 1), 1)
        if st["bytes"] > 0 and st["flops"] > 0:
            cost = roofline.KernelCost(st["bytes"], st["flops"])
            st.update(roofline.achieved(cost, st["total_us"] / 1e6, peaks))
        else:  # non-kernel stage: no model to place on the roofline
            st.pop("bytes"), st.pop("flops")
    wall_us = sum(s.duration_us for s in spans if s.parent == -1)
    return {
        "wall_us": round(wall_us, 1),
        "n_spans": len(spans),
        "coverage": round(coverage(spans), 4),
        "stages": stages,
    }
