"""Process-global metrics registry: counters, gauges, histograms.

The quality half of ``repro.obs`` (DESIGN.md §13) needs a place for
*numbers that outlive one trace*: request counts, recall gauges, drift
scores, latency histograms.  This module is that backbone — one
process-global :class:`MetricsRegistry` every subsystem reports
through (``serve.metrics`` re-routes its counters here, the tracer's
drop counter is exported as a pull-time gauge, the quality auditor and
drift monitor publish their estimates), exposed in one place via
Prometheus text exposition (:meth:`MetricsRegistry.to_prometheus`).

Design constraints:

  * label sets, bounded cardinality.  Every metric accepts a fixed
    label-name tuple at registration; each distinct label-value tuple
    is one series.  Series count is BOUNDED (``max_series``): past the
    bound, new label sets are dropped and counted in
    ``dropped_series`` instead of stored, so a mis-labeled hot path
    (e.g. a per-request id leaking into a label) cannot grow the
    registry without bound — the same discipline as the tracer's
    bounded span collector.
  * snapshot/delta semantics.  ``snapshot()`` freezes every series
    into plain nested dicts (JSON-serializable as-is);
    ``delta(cur, prev)`` subtracts counter-like values series-wise so
    callers can rate over an interval without the registry itself
    keeping history.
  * exemplars on histograms.  Observations landing at the top of a
    histogram's range may carry an exemplar payload (e.g. a request's
    span breakdown); the histogram retains the ``max_exemplars``
    LARGEST observations per series, so ``slowest(n)`` answers *why*
    the p99 was slow, not just that it was.
  * pull-time gauges.  ``Gauge.set_fn`` registers a callable sampled
    at snapshot/exposition time — how the tracer's live drop counter
    is exported without the tracer importing this module.

Single-threaded by design, like the rest of the serving stack: the
scheduler is cooperative, so metrics need no locks.

Usage::

    from repro.obs import metrics

    reg = metrics.get_registry()
    reqs = reg.counter("serve_requests_total", "requests by status",
                       labels=("status",))
    reqs.inc(status="ok")
    print(reg.to_prometheus())
"""
from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "DEFAULT_BUCKETS"]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default histogram bucket upper bounds (seconds-flavored, spanning
#: µs-scale cache hits to second-scale stalls)
DEFAULT_BUCKETS = (1e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5,
                   1.0, 5.0)


def _escape_label_value(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _series_str(name: str, labels: tuple[tuple[str, str], ...],
                extra: tuple[tuple[str, str], ...] = ()) -> str:
    pairs = labels + extra
    if not pairs:
        return name
    inner = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in pairs)
    return f"{name}{{{inner}}}"


class _Metric:
    """Shared series bookkeeping: labels → one series, bounded count."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: tuple[str, ...] = (),
                 max_series: int = 64):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = str(help)
        self.label_names = tuple(labels)
        self.max_series = int(max_series)
        self.dropped_series = 0  # label sets refused past max_series
        self._series: dict[tuple[str, ...], object] = {}

    def _key(self, labels: dict) -> tuple[str, ...] | None:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        key = tuple(str(labels[ln]) for ln in self.label_names)
        if key not in self._series and len(self._series) >= self.max_series:
            self.dropped_series += 1
            return None
        return key

    def _labeled(self, key: tuple[str, ...]) -> tuple[tuple[str, str], ...]:
        return tuple(zip(self.label_names, key))

    @property
    def series_count(self) -> int:
        return len(self._series)

    def clear(self) -> None:
        self._series.clear()
        self.dropped_series = 0


class Counter(_Metric):
    """Monotone counter; one float per label set."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        if key is None:
            return
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def get(self, **labels) -> float:
        key = tuple(str(labels[ln]) for ln in self.label_names)
        return float(self._series.get(key, 0.0))

    def collect(self) -> dict[tuple[str, ...], float]:
        return {k: float(v) for k, v in self._series.items()}

    def expose(self, lines: list[str]) -> None:
        for key in sorted(self._series):
            lines.append(f"{_series_str(self.name, self._labeled(key))} "
                         f"{_fmt_value(self._series[key])}")


class Gauge(_Metric):
    """Set-to-current-value metric; supports pull-time callables."""

    kind = "gauge"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._fns: dict[tuple[str, ...], object] = {}

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        if key is None:
            return
        self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        if key is None:
            return
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def set_fn(self, fn, **labels) -> None:
        """Sample ``fn()`` at collection time (snapshot / exposition)
        instead of storing a value — for live counters owned elsewhere
        (e.g. the tracer's drop count)."""
        key = self._key(labels)
        if key is None:
            return
        self._series.setdefault(key, 0.0)
        self._fns[key] = fn

    def get(self, **labels) -> float:
        key = tuple(str(labels[ln]) for ln in self.label_names)
        fn = self._fns.get(key)
        if fn is not None:
            return float(fn())
        return float(self._series.get(key, 0.0))

    def collect(self) -> dict[tuple[str, ...], float]:
        out = {}
        for k, v in self._series.items():
            fn = self._fns.get(k)
            out[k] = float(fn()) if fn is not None else float(v)
        return out

    def expose(self, lines: list[str]) -> None:
        for key, val in sorted(self.collect().items()):
            lines.append(f"{_series_str(self.name, self._labeled(key))} "
                         f"{_fmt_value(val)}")

    def clear(self) -> None:
        super().clear()
        self._fns.clear()


@dataclasses.dataclass
class _HistSeries:
    counts: list[int]  # per finite bucket, non-cumulative
    overflow: int = 0  # observations past the last finite bucket
    total: int = 0
    sum: float = 0.0
    # (value, payload) exemplars of the LARGEST observations, unsorted
    exemplars: list[tuple[float, dict]] = dataclasses.field(
        default_factory=list)


class Histogram(_Metric):
    """Fixed-bucket histogram with top-value exemplar retention.

    ``observe(v, exemplar={...})`` files v into its bucket and — when
    an exemplar payload is given — retains it if v ranks among the
    ``max_exemplars`` largest observations of its series so far.
    ``slowest(n)`` returns those payloads value-descending: the tail
    attribution a plain histogram cannot give.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, labels: tuple[str, ...] = (),
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS,
                 max_series: int = 64, max_exemplars: int = 8):
        super().__init__(name, help, labels, max_series)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs or any(not math.isfinite(b) for b in bs):
            raise ValueError("buckets must be finite and non-empty")
        self.buckets = bs
        self.max_exemplars = int(max_exemplars)

    def _rec(self, labels: dict) -> _HistSeries | None:
        key = self._key(labels)
        if key is None:
            return None
        rec = self._series.get(key)
        if rec is None:
            rec = self._series[key] = _HistSeries([0] * len(self.buckets))
        return rec

    def observe(self, value: float, exemplar: dict | None = None,
                **labels) -> None:
        rec = self._rec(labels)
        if rec is None:
            return
        v = float(value)
        rec.total += 1
        rec.sum += v
        placed = False
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                rec.counts[i] += 1
                placed = True
                break
        if not placed:
            rec.overflow += 1
        if exemplar is not None:
            ex = rec.exemplars
            if len(ex) < self.max_exemplars:
                ex.append((v, dict(exemplar)))
            else:
                jmin = min(range(len(ex)), key=lambda j: ex[j][0])
                if v > ex[jmin][0]:
                    ex[jmin] = (v, dict(exemplar))

    def slowest(self, n: int = 5, **labels) -> list[tuple[float, dict]]:
        """The n largest retained (value, exemplar) pairs, descending.
        With no labels given, pools every series."""
        if labels:
            key = tuple(str(labels[ln]) for ln in self.label_names)
            recs = [self._series[key]] if key in self._series else []
        else:
            recs = list(self._series.values())
        pool = [e for r in recs for e in r.exemplars]
        pool.sort(key=lambda t: -t[0])
        return pool[:n]

    def collect(self) -> dict[tuple[str, ...], dict]:
        out = {}
        for key, rec in self._series.items():
            out[key] = {
                "buckets": {ub: c for ub, c in zip(self.buckets, rec.counts)},
                "count": rec.total, "sum": rec.sum,
            }
        return out

    def expose(self, lines: list[str]) -> None:
        for key in sorted(self._series):
            rec = self._series[key]
            lab = self._labeled(key)
            cum = 0
            for ub, c in zip(self.buckets, rec.counts):
                cum += c
                lines.append(
                    f"{_series_str(self.name + '_bucket', lab, (('le', _fmt_value(ub)),))} "
                    f"{cum}")
            lines.append(
                f"{_series_str(self.name + '_bucket', lab, (('le', '+Inf'),))} "
                f"{rec.total}")
            lines.append(f"{_series_str(self.name + '_sum', lab)} "
                         f"{_fmt_value(rec.sum)}")
            lines.append(f"{_series_str(self.name + '_count', lab)} "
                         f"{rec.total}")


class MetricsRegistry:
    """Name → metric map with get-or-create registration.

    Re-registering an existing name returns the SAME metric object
    when kind and label names agree (so modules can idempotently
    declare what they report through), and raises on a mismatch.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str, labels, **kw):
        existing = self._metrics.get(name)
        if existing is not None:
            if type(existing) is not cls or existing.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind}{existing.label_names}")
            return existing
        m = cls(name, help, tuple(labels), **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = (), **kw) -> Counter:
        return self._get_or_create(Counter, name, help, labels, **kw)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = (), **kw) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels, **kw)

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (), **kw) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels, **kw)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    # -- snapshot / delta -------------------------------------------------

    def snapshot(self) -> dict:
        """Freeze every series into plain nested dicts:
        ``{name: {"kind": ..., "series": {"a=1,b=x": value}}}`` —
        JSON-serializable as-is (histogram values are sub-dicts with
        bucket counts / count / sum)."""
        out = {}
        for name, m in sorted(self._metrics.items()):
            series = {}
            for key, val in m.collect().items():
                skey = ",".join(f"{ln}={v}" for ln, v
                                in zip(m.label_names, key)) or ""
                series[skey] = val
            out[name] = {"kind": m.kind, "series": series,
                         "dropped_series": m.dropped_series}
        return out

    @staticmethod
    def delta(cur: dict, prev: dict) -> dict:
        """Series-wise ``cur − prev`` for counter-like values (counters
        and histogram counts/sums); gauges pass through ``cur``.
        Series absent from ``prev`` difference against zero."""
        out = {}
        for name, block in cur.items():
            pseries = prev.get(name, {}).get("series", {})
            dser = {}
            for skey, val in block["series"].items():
                pv = pseries.get(skey)
                if block["kind"] == "counter":
                    dser[skey] = val - (pv or 0.0)
                elif block["kind"] == "histogram":
                    pv = pv or {"buckets": {}, "count": 0, "sum": 0.0}
                    dser[skey] = {
                        "buckets": {ub: c - pv["buckets"].get(ub, 0)
                                    for ub, c in val["buckets"].items()},
                        "count": val["count"] - pv["count"],
                        "sum": val["sum"] - pv["sum"],
                    }
                else:  # gauge: a delta of a level is rarely meaningful
                    dser[skey] = val
            out[name] = {"kind": block["kind"], "series": dser}
        return out

    # -- exposition -------------------------------------------------------

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4) of every metric."""
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            m.expose(lines)
        return "\n".join(lines) + "\n" if lines else ""

    def reset(self) -> None:
        """Drop every registered metric (tests only)."""
        self._metrics.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY
