"""Atomic, checksummed snapshots of StreamingIndex state.

A snapshot is a directory ``snap_<lsn:012d>/`` committed with the
COMMIT-marker protocol from :mod:`repro.resilience.fsio` (fsync files →
fsync dir → COMMIT → rename → fsync parent), so a reader that requires
the marker never sees a torn snapshot.  Contents:

    meta.msgpack    lsn, d, total, counters, per-array blake2b checksums
    seg_<i>.npz     one sealed segment: global ids + float32 rows
    delta.npz       the unsealed delta buffer (ids + rows)
    alive.npz       packed liveness bitmap over ids [0, total)
    COMMIT          written last by fsio.commit_dir

Checksums are CONTENT checksums — blake2b over each array's dtype,
shape, and raw bytes — stored in the meta and re-verified on load.  A
bit flip in array data fails the checksum; a bit flip in npz container
structure fails parsing; both surface as :class:`CorruptSegmentError`
and the snapshot is refused — recovery raises rather than serving
corrupted rows.  Segment
*backends* are not serialized: load returns raw (ids, vectors) runs and
recovery rebuilds each backend deterministically from its rows — the
same codec-per-seal discipline the live index uses, so quantized
segments come back with identical codes.

Chaos sites: ``snapshot.write`` (before payloads), ``snapshot.commit``
(before the marker), ``segment.load`` (byte transform on each payload
file read — how bit-flip injection exercises the checksums).
"""
from __future__ import annotations

import hashlib
import io
import os
import shutil
import time
from pathlib import Path

import msgpack
import numpy as np

from . import chaos
from .fsio import COMMIT_MARKER, commit_dir

__all__ = ["CorruptSegmentError", "SnapshotState", "content_checksum",
           "write_snapshot", "load_snapshot", "latest_snapshot",
           "snapshot_lsn"]

_PREFIX = "snap_"


class CorruptSegmentError(RuntimeError):
    """A snapshot payload failed verification — the structured refusal
    the recovery path raises instead of serving corrupted rows."""

    def __init__(self, path, reason: str, *, expected: str | None = None,
                 actual: str | None = None):
        self.path = Path(path)
        self.reason = reason
        self.expected = expected
        self.actual = actual
        detail = f"{self.path.name}: {reason}"
        if expected is not None:
            detail += f" (expected {expected}, got {actual})"
        super().__init__(detail)


def content_checksum(*arrays: np.ndarray) -> str:
    """blake2b hex digest over each array's dtype, shape, and bytes."""
    h = hashlib.blake2b(digest_size=16)
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class SnapshotState:
    """Decoded, checksum-verified snapshot contents."""

    def __init__(self, *, lsn: int, d: int, total: int, n_flushes: int,
                 n_compactions: int,
                 segments: list[tuple[np.ndarray, np.ndarray]],
                 delta_ids: np.ndarray, delta_vectors: np.ndarray,
                 alive: np.ndarray, bytes_verified: int):
        self.lsn = lsn
        self.d = d
        self.total = total
        self.n_flushes = n_flushes
        self.n_compactions = n_compactions
        self.segments = segments  # [(global ids int64, rows float32)]
        self.delta_ids = delta_ids
        self.delta_vectors = delta_vectors
        self.alive = alive  # bool, shape (total,)
        self.bytes_verified = bytes_verified


def _save_npz(path: Path, **arrays) -> str:
    np.savez(path, **arrays)
    return content_checksum(*arrays.values())


def write_snapshot(directory: str | os.PathLike, index, lsn: int) -> Path:
    """Atomically snapshot ``index`` (a StreamingIndex) as of WAL
    position ``lsn`` (the last applied record).  Returns the committed
    snapshot directory."""
    directory = Path(directory)
    final = directory / f"{_PREFIX}{lsn:012d}"
    tmp = final.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    chaos.hit("snapshot.write")

    checksums: dict[str, str] = {}
    seg_meta = []
    for i, seg in enumerate(index.segments):
        name = f"seg_{i}.npz"
        checksums[name] = _save_npz(tmp / name, ids=seg.ids,
                                    vectors=index._store[seg.ids])
        seg_meta.append({"file": name, "n": int(seg.size)})
    checksums["delta.npz"] = _save_npz(
        tmp / "delta.npz", ids=index.delta.ids, vectors=index.delta.vectors)
    total = int(index._total)
    checksums["alive.npz"] = _save_npz(
        tmp / "alive.npz", bits=np.packbits(index._alive[:total]))
    meta = {
        "format": 1,
        "lsn": int(lsn),
        "d": int(index.d),
        "total": total,
        "n_flushes": int(index.n_flushes),
        "n_compactions": int(index.n_compactions),
        "segments": seg_meta,
        "checksums": checksums,
    }
    (tmp / "meta.msgpack").write_bytes(msgpack.packb(meta))
    chaos.hit("snapshot.commit")
    return commit_dir(tmp, final)


def snapshot_lsn(path: str | os.PathLike) -> int:
    return int(Path(path).name[len(_PREFIX):])


def latest_snapshot(directory: str | os.PathLike) -> Path | None:
    """Newest COMMITted snapshot dir under ``directory`` (None if no
    snapshot has ever committed)."""
    directory = Path(directory)
    if not directory.exists():
        return None
    best = None
    for p in directory.iterdir():
        if (p.is_dir() and p.name.startswith(_PREFIX)
                and not p.name.endswith(".tmp")
                and (p / COMMIT_MARKER).exists()):
            if best is None or snapshot_lsn(p) > snapshot_lsn(best):
                best = p
    return best


def _load_npz(path: Path, expected_checksum: str,
              names: tuple[str, ...]) -> tuple[list[np.ndarray], int]:
    """Read one payload file through the chaos transform, parse it, and
    verify its content checksum.  Returns (arrays, bytes verified)."""
    blob = path.read_bytes()
    blob = chaos.transform("segment.load", blob)
    try:
        with np.load(io.BytesIO(blob)) as z:
            arrays = [np.asarray(z[n]) for n in names]
    except Exception as e:
        raise CorruptSegmentError(path, f"unparseable payload ({e})") from e
    actual = content_checksum(*arrays)
    if actual != expected_checksum:
        raise CorruptSegmentError(path, "content checksum mismatch",
                                  expected=expected_checksum, actual=actual)
    return arrays, len(blob)


def load_snapshot(path: str | os.PathLike) -> SnapshotState:
    """Decode and VERIFY a committed snapshot.  Raises
    :class:`CorruptSegmentError` on any integrity failure — a refused
    snapshot is never partially applied."""
    t0 = time.perf_counter()
    path = Path(path)
    if not (path / COMMIT_MARKER).exists():
        raise CorruptSegmentError(path, "missing COMMIT marker "
                                        "(uncommitted or torn snapshot)")
    try:
        meta = msgpack.unpackb((path / "meta.msgpack").read_bytes())
    except Exception as e:
        raise CorruptSegmentError(path, f"unreadable meta ({e})") from e
    checksums = meta["checksums"]
    nbytes = 0

    segments = []
    for ent in meta["segments"]:
        (ids, vectors), nb = _load_npz(path / ent["file"],
                                       checksums[ent["file"]],
                                       ("ids", "vectors"))
        if ids.shape[0] != ent["n"]:
            raise CorruptSegmentError(
                path / ent["file"], "row count mismatch",
                expected=str(ent["n"]), actual=str(ids.shape[0]))
        segments.append((ids.astype(np.int64),
                         vectors.astype(np.float32, copy=False)))
        nbytes += nb
    (delta_ids, delta_vectors), nb = _load_npz(
        path / "delta.npz", checksums["delta.npz"], ("ids", "vectors"))
    nbytes += nb
    (bits,), nb = _load_npz(path / "alive.npz", checksums["alive.npz"],
                            ("bits",))
    nbytes += nb
    total = int(meta["total"])
    alive = np.unpackbits(bits)[:total].astype(bool)

    state = SnapshotState(
        lsn=int(meta["lsn"]), d=int(meta["d"]), total=total,
        n_flushes=int(meta["n_flushes"]),
        n_compactions=int(meta["n_compactions"]),
        segments=segments, delta_ids=delta_ids.astype(np.int64),
        delta_vectors=delta_vectors.astype(np.float32, copy=False),
        alive=alive, bytes_verified=nbytes)
    state.load_seconds = time.perf_counter() - t0
    return state
