"""Durability orchestration: WAL-before-memory logging + recovery.

:class:`DurabilityManager` is the piece StreamingIndex owns when built
with ``options={"durability": {"dir": ...}}``.  The contract
(DESIGN.md §14):

    log(op)  →  [chaos: stream.apply]  →  mutate memory

Every mutation appends its WAL record (fsynced by default) BEFORE the
in-memory state changes, so the durable log prefix always dominates
memory: a crash at any point loses at most the single op whose record
never hit the disk.  Snapshots bound replay length — every
``snapshot_every`` records the manager writes an atomic snapshot
(:mod:`repro.resilience.snapshot`) and rotates the WAL to a fresh
file whose base LSN starts past the snapshot.

``recover(dir)`` rebuilds an index from disk alone:

    1. scan the WAL; a torn tail (first bad record onward) is
       physically truncated — torn bytes are NEVER replayed;
    2. load + checksum-verify the newest committed snapshot (a
       corrupt snapshot raises CorruptSegmentError — refusal, not
       best-effort);
    3. replay WAL records with lsn > snapshot lsn through the normal
       insert/delete/flush code paths (auto-flush and compaction are
       deterministic functions of the op sequence, so derived "flush"
       and "compact" records replay as no-ops);
    4. re-attach a DurabilityManager continuing at the next LSN.

Replay equivalence — the recovered index's ``live_ids`` and search
results match a never-crashed twin exactly — is the acceptance test
(tests/test_resilience.py kill-point sweep).
"""
from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Any, Mapping

import msgpack
import numpy as np

from .fsio import fsync_dir, write_file_durable
from .snapshot import (CorruptSegmentError, latest_snapshot, load_snapshot,
                       write_snapshot)
from .wal import WriteAheadLog, scan_wal, truncate_wal

__all__ = ["DurabilityManager", "RecoveryReport", "RecoveryError", "recover"]

_WAL_NAME = "wal.log"
_CONFIG_NAME = "config.msgpack"


class RecoveryError(RuntimeError):
    """The WAL and snapshot disagree — replay cannot proceed safely."""


class RecoveryReport:
    """What ``recover`` did: replay volume, verification, wall time."""

    def __init__(self, *, snapshot_lsn: int | None, records_replayed: int,
                 records_skipped: int, torn_bytes_truncated: int,
                 bytes_verified: int, wall_seconds: float):
        self.snapshot_lsn = snapshot_lsn  # None = no snapshot, full replay
        self.records_replayed = records_replayed
        self.records_skipped = records_skipped  # lsn <= snapshot (already in)
        self.torn_bytes_truncated = torn_bytes_truncated
        self.bytes_verified = bytes_verified  # snapshot payload bytes checked
        self.wall_seconds = wall_seconds

    def __repr__(self) -> str:
        return (f"RecoveryReport(snapshot_lsn={self.snapshot_lsn}, "
                f"replayed={self.records_replayed}, "
                f"skipped={self.records_skipped}, "
                f"torn_bytes={self.torn_bytes_truncated}, "
                f"verified_bytes={self.bytes_verified}, "
                f"wall={self.wall_seconds:.3f}s)")


def _thaw(value: Any) -> Any:
    """FrozenOptions/tuples → plain dict/list (msgpack-serializable)."""
    if isinstance(value, Mapping):
        return {k: _thaw(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_thaw(v) for v in value]
    return value


def _metrics():
    from repro.obs.metrics import get_registry

    reg = get_registry()
    return {
        "fsync": reg.histogram(
            "wal_fsync_seconds", "per-append WAL fsync latency"),
        "records": reg.counter(
            "wal_records_total", "WAL records appended by op",
            labels=("op",)),
        "replayed": reg.counter(
            "recovery_replayed_total", "WAL records replayed by recover()"),
        "snapshots": reg.counter(
            "snapshot_commits_total", "atomic snapshots committed"),
    }


class DurabilityManager:
    """WAL + snapshot lifecycle for one StreamingIndex directory."""

    def __init__(self, directory: str | os.PathLike, *, d: int,
                 config=None, sync: bool = True, snapshot_every: int = 0,
                 snapshot_keep: int = 2, fresh: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        if fresh:
            # a fresh build must not silently append onto an existing
            # durable index — that history belongs to recover()
            wal_path = self.dir / _WAL_NAME
            has_history = latest_snapshot(self.dir) is not None
            if not has_history and wal_path.exists():
                try:
                    has_history = bool(scan_wal(wal_path)[1])
                except ValueError:
                    has_history = True
            if has_history:
                raise RecoveryError(
                    f"{self.dir} already holds a durable index; recover "
                    "it with repro.resilience.recover() or point "
                    "durability at an empty directory")
        self.sync = bool(sync)
        self.snapshot_every = int(snapshot_every)  # 0 = manual only
        self.snapshot_keep = max(int(snapshot_keep), 1)
        self.records_since_snapshot = 0
        self._m = _metrics()
        # persist (config, d, durability settings) so recover(dir) is
        # self-contained — no caller-side config plumbing on restart
        cfg_path = self.dir / _CONFIG_NAME
        if config is not None and not cfg_path.exists():
            opts = {k: _thaw(v) for k, v in config.options.items()
                    if k != "durability"}
            write_file_durable(cfg_path, msgpack.packb({
                "d": int(d),
                "config": {
                    "backend": config.backend, "c": config.c,
                    "cp_c": config.cp_c, "m": config.m,
                    "seed": config.seed, "default_k": config.default_k,
                    "options": opts,
                },
                "durability": {"sync": self.sync,
                               "snapshot_every": self.snapshot_every,
                               "snapshot_keep": self.snapshot_keep},
            }))
            fsync_dir(self.dir)
        self.wal = WriteAheadLog(
            self.dir / _WAL_NAME, sync=self.sync,
            fsync_observer=self._m["fsync"].observe)

    # -- logging (call BEFORE the in-memory mutation) --------------------

    def _append(self, payload: dict) -> int:
        lsn = self.wal.append(payload)
        self._m["records"].inc(op=payload["op"])
        self.records_since_snapshot += 1
        return lsn

    def log_insert(self, id0: int, x: np.ndarray) -> int:
        x = np.ascontiguousarray(x, dtype=np.float32)
        return self._append({"op": "insert", "id0": int(id0),
                             "n": int(x.shape[0]), "d": int(x.shape[1]),
                             "vec": x.tobytes()})

    def log_delete(self, ids: np.ndarray) -> int:
        ids = np.ascontiguousarray(ids, dtype=np.int64)
        return self._append({"op": "delete", "ids": ids.tobytes()})

    def log_flush(self) -> int:
        return self._append({"op": "flush"})

    def log_compact(self) -> int:
        return self._append({"op": "compact"})

    # -- snapshots -------------------------------------------------------

    def maybe_snapshot(self, index) -> Path | None:
        if (self.snapshot_every > 0
                and self.records_since_snapshot >= self.snapshot_every):
            return self.snapshot(index)
        return None

    def snapshot(self, index) -> Path:
        """Snapshot ``index`` as of the last applied record, rotate the
        WAL past it, and GC old snapshots.  Crash-safe at every step:
        before the COMMIT the old snapshot+WAL still recover; between
        COMMIT and rotation the WAL's overlap with the snapshot is
        skipped at replay (lsn <= snapshot lsn)."""
        last_lsn = self.wal.next_lsn - 1
        path = write_snapshot(self.dir, index, last_lsn)
        self._m["snapshots"].inc()
        self._rotate(self.wal.next_lsn)
        self.records_since_snapshot = 0
        self._gc(keep=self.snapshot_keep)
        return path

    def _rotate(self, base_lsn: int) -> None:
        self.wal.close()
        wal_path = self.dir / _WAL_NAME
        tmp = self.dir / (_WAL_NAME + ".new")
        tmp.unlink(missing_ok=True)  # a crashed rotation may have left one
        fresh = WriteAheadLog(tmp, base_lsn=base_lsn, sync=self.sync)
        fresh.close()
        os.replace(tmp, wal_path)
        fsync_dir(self.dir)
        self.wal = WriteAheadLog(wal_path, sync=self.sync,
                                 fsync_observer=self._m["fsync"].observe)

    def _gc(self, keep: int) -> None:
        import shutil

        from .snapshot import _PREFIX, snapshot_lsn

        snaps = sorted((p for p in self.dir.iterdir()
                        if p.is_dir() and p.name.startswith(_PREFIX)),
                       key=lambda p: (p.name.endswith(".tmp"),
                                      snapshot_lsn(p.with_suffix(""))
                                      if p.name.endswith(".tmp")
                                      else snapshot_lsn(p)))
        committed = [p for p in snaps if not p.name.endswith(".tmp")]
        stale = ([p for p in snaps if p.name.endswith(".tmp")]
                 + committed[:-keep])
        for p in stale:
            shutil.rmtree(p, ignore_errors=True)

    def close(self) -> None:
        self.wal.close()


# -- recovery ---------------------------------------------------------------


def load_config(directory: str | os.PathLike) -> dict:
    """The persisted (config, d, durability) block for ``directory``."""
    path = Path(directory) / _CONFIG_NAME
    if not path.exists():
        raise RecoveryError(f"{directory}: no {_CONFIG_NAME} — not a "
                            "durability directory")
    return msgpack.unpackb(path.read_bytes())


def recover(directory: str | os.PathLike):
    """Rebuild a StreamingIndex from ``directory`` after a crash.

    Returns ``(index, RecoveryReport)``.  The index comes back with a
    live DurabilityManager attached, continuing the WAL at the next
    LSN.  Raises :class:`CorruptSegmentError` if the newest committed
    snapshot fails verification and :class:`RecoveryError` if the WAL
    contradicts the snapshot.
    """
    from repro.index.config import IndexConfig
    from repro.index.registry import build_index

    t0 = time.perf_counter()
    directory = Path(directory)
    blob = load_config(directory)
    d = int(blob["d"])
    cfg = blob["config"]
    config = IndexConfig(backend=cfg["backend"], c=cfg["c"],
                         cp_c=cfg["cp_c"], m=cfg["m"], seed=cfg["seed"],
                         default_k=cfg["default_k"],
                         options=cfg.get("options", {}))
    dur = blob.get("durability", {})

    # 1. WAL scan + torn-tail truncation
    wal_path = directory / _WAL_NAME
    records, torn = [], 0
    if wal_path.exists():
        _, records, valid = scan_wal(wal_path)
        torn = wal_path.stat().st_size - valid
        if torn:
            truncate_wal(wal_path, valid)

    # 2. newest committed snapshot (verified; refusal raises)
    snap_path = latest_snapshot(directory)
    state = load_snapshot(snap_path) if snap_path is not None else None
    if state is not None and state.d != d:
        raise RecoveryError(f"snapshot d={state.d} != config d={d}")

    # 3. empty index, snapshot applied, WAL tail replayed
    index = build_index(np.empty((0, d), dtype=np.float32), config)
    if state is not None:
        _apply_snapshot(index, state)
    snap_lsn = state.lsn if state is not None else None
    replayed = skipped = 0
    for rec in records:
        if snap_lsn is not None and rec.lsn <= snap_lsn:
            skipped += 1
            continue
        _apply_record(index, rec)
        replayed += 1
    _metrics()["replayed"].inc(replayed)

    # 4. continue the WAL where it left off
    index.durability = DurabilityManager(
        directory, d=d, config=None, sync=bool(dur.get("sync", True)),
        snapshot_every=int(dur.get("snapshot_every", 0)),
        snapshot_keep=int(dur.get("snapshot_keep", 2)))
    index.durability.records_since_snapshot = replayed

    report = RecoveryReport(
        snapshot_lsn=snap_lsn, records_replayed=replayed,
        records_skipped=skipped, torn_bytes_truncated=torn,
        bytes_verified=state.bytes_verified if state is not None else 0,
        wall_seconds=time.perf_counter() - t0)
    return index, report


def _apply_snapshot(index, state) -> None:
    """Install verified snapshot contents into a freshly built (empty)
    StreamingIndex.  Backends are rebuilt from raw rows — bitwise the
    same result as the original seal (codec training is deterministic
    over the same rows)."""
    from repro.stream.segment import Segment

    total = state.total
    index._grow_to(total)
    index._alive[:total] = state.alive
    index._total = total
    index._n_live = int(state.alive.sum())
    index.n_flushes = state.n_flushes
    index.n_compactions = state.n_compactions
    for ids, vectors in state.segments:
        index._store[ids] = vectors
        seg = Segment(ids, vectors, index.config, index.segment_backend)
        seg.dead = int(ids.size - state.alive[ids].sum())
        index._owner[ids] = seg.serial
        index._by_serial[seg.serial] = seg
        index.segments.append(seg)
    if state.delta_ids.size:
        index._store[state.delta_ids] = state.delta_vectors
        index.delta.insert(state.delta_ids, state.delta_vectors)
    if index.drift is not None and index._n_live:
        live = index.live_ids()
        index.drift.observe_rows(index._store[live] @ index._drift_proj)


def _apply_record(index, rec) -> None:
    p = rec.payload
    op = p.get("op")
    if op == "insert":
        if index._total != p["id0"]:
            raise RecoveryError(
                f"WAL record lsn={rec.lsn} inserts at id {p['id0']} but "
                f"index has assigned {index._total} ids — log and "
                "snapshot disagree")
        x = np.frombuffer(p["vec"], dtype=np.float32).reshape(p["n"], p["d"])
        index.insert(x)
    elif op == "delete":
        ids = np.frombuffer(p["ids"], dtype=np.int64)
        index.delete(ids)
    elif op == "flush":
        index.flush()  # no-op when replayed inserts already auto-flushed
    elif op == "compact":
        pass  # derived event: compaction re-fires inside delete/flush
    else:
        raise RecoveryError(f"unknown WAL op {op!r} at lsn={rec.lsn}")
