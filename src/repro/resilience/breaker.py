"""Circuit breaker: stop calling a failing dependency until it heals.

Classic three-state machine (DESIGN.md §14) guarding the serve
scheduler's degraded-tier hedge target:

    CLOSED      normal operation; outcomes recorded in a sliding
                window of the last ``window`` calls.  When the window
                holds >= ``min_calls`` outcomes and the failure rate
                reaches ``failure_threshold``, trip to OPEN.
    OPEN        calls are refused (``allow()`` is False) for
                ``reset_timeout_s``; after it elapses the next
                ``allow()`` transitions to HALF_OPEN and admits one
                probe.
    HALF_OPEN   exactly one in-flight probe: success -> CLOSED (window
                cleared), failure -> OPEN (timer restarted).

The breaker is clock-injected (monotonic seconds) so tests drive it
deterministically, and ``on_transition`` lets callers mirror state
into a metrics gauge.
"""
from __future__ import annotations

import collections
import time
from typing import Callable

__all__ = ["CircuitBreaker", "STATE_CLOSED", "STATE_OPEN", "STATE_HALF_OPEN"]

STATE_CLOSED = "closed"
STATE_OPEN = "open"
STATE_HALF_OPEN = "half_open"

#: gauge encoding of breaker state (exported for dashboards/tests)
STATE_CODES = {STATE_CLOSED: 0.0, STATE_OPEN: 1.0, STATE_HALF_OPEN: 2.0}


class CircuitBreaker:
    """Sliding-window failure-rate breaker with clock injection."""

    def __init__(self, *, window: int = 16, failure_threshold: float = 0.5,
                 min_calls: int = 4, reset_timeout_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str, str], None] | None = None):
        if window < 1:
            raise ValueError("window must be >= 1")
        if not 0.0 < failure_threshold <= 1.0:
            raise ValueError("failure_threshold must be in (0, 1]")
        self.window = int(window)
        self.failure_threshold = float(failure_threshold)
        self.min_calls = max(int(min_calls), 1)
        self.reset_timeout_s = float(reset_timeout_s)
        self._clock = clock
        self._on_transition = on_transition
        self._state = STATE_CLOSED
        self._outcomes: collections.deque[bool] = collections.deque(
            maxlen=self.window)  # True = failure
        self._opened_at = 0.0
        self._probe_in_flight = False
        self.transitions = 0  # lifetime transition count

    # -- state -----------------------------------------------------------

    @property
    def state(self) -> str:
        return self._state

    def state_code(self) -> float:
        """Numeric encoding for the metrics gauge (0/1/2)."""
        return STATE_CODES[self._state]

    def _transition(self, new: str) -> None:
        if new == self._state:
            return
        old, self._state = self._state, new
        self.transitions += 1
        if new == STATE_OPEN:
            self._opened_at = self._clock()
            self._probe_in_flight = False
        elif new == STATE_CLOSED:
            self._outcomes.clear()
            self._probe_in_flight = False
        if self._on_transition is not None:
            self._on_transition(old, new)

    # -- call protocol ---------------------------------------------------

    def allow(self) -> bool:
        """May the caller attempt the guarded call right now?"""
        if self._state == STATE_CLOSED:
            return True
        if self._state == STATE_OPEN:
            if self._clock() - self._opened_at >= self.reset_timeout_s:
                self._transition(STATE_HALF_OPEN)
            else:
                return False
        # HALF_OPEN: admit exactly one probe at a time
        if self._probe_in_flight:
            return False
        self._probe_in_flight = True
        return True

    def record_success(self) -> None:
        if self._state == STATE_HALF_OPEN:
            self._transition(STATE_CLOSED)
            return
        self._outcomes.append(False)

    def record_failure(self) -> None:
        if self._state == STATE_HALF_OPEN:
            self._transition(STATE_OPEN)
            return
        self._outcomes.append(True)
        if (self._state == STATE_CLOSED
                and len(self._outcomes) >= self.min_calls):
            rate = sum(self._outcomes) / len(self._outcomes)
            if rate >= self.failure_threshold:
                self._transition(STATE_OPEN)

    def failure_rate(self) -> float:
        if not self._outcomes:
            return 0.0
        return sum(self._outcomes) / len(self._outcomes)
