"""Write-ahead log for StreamingIndex mutations (DESIGN.md §14).

One append-only file of checksummed, length-prefixed records.  Layout:

    header   MAGIC "PMWAL001" (8B)  |  base_lsn  <Q (8B)
    record   <IQ8s  =  payload_len (4B) | lsn (8B) | blake2b-8 digest
             followed by `payload_len` bytes of msgpack payload

The digest covers ``lsn_bytes + payload`` so a record cannot be
spliced to a different position, and the length prefix lets the reader
detect a torn tail: scanning stops at the FIRST record whose header is
incomplete, whose payload is short, or whose digest mismatches —
everything at and past that offset is presumed torn by a crash and is
truncated before the log is reopened for append (torn tails are never
replayed).

Payload dicts (op-specific):

    {"op": "insert", "id0": int, "n": int, "d": int, "vec": bytes}
        vec = raw little-endian float32, n*d values; ids are always
        the contiguous range [id0, id0+n) (StreamingIndex invariant)
    {"op": "delete", "ids": bytes}      raw little-endian int64 ids
    {"op": "flush"}                     explicit delta seal
    {"op": "compact"}                   explicit compaction request

The WAL-before-memory contract lives in the caller
(``recovery.DurabilityManager``): a record is appended (and optionally
fsynced) BEFORE the in-memory mutation, so the durable prefix of the
log always dominates the in-memory state.
"""
from __future__ import annotations

import os
import struct
import time
from pathlib import Path

import msgpack

from . import chaos
from .fsio import fsync_path

__all__ = ["WriteAheadLog", "WalRecord", "scan_wal", "MAGIC",
           "HEADER_SIZE", "RECORD_HEADER"]

MAGIC = b"PMWAL001"
RECORD_HEADER = struct.Struct("<IQ8s")  # payload_len, lsn, digest
HEADER_SIZE = len(MAGIC) + 8  # magic + base_lsn
_DIGEST_SIZE = 8


def _digest(lsn: int, payload: bytes) -> bytes:
    import hashlib

    return hashlib.blake2b(lsn.to_bytes(8, "little") + payload,
                           digest_size=_DIGEST_SIZE).digest()


class WalRecord:
    """One decoded WAL record."""

    __slots__ = ("lsn", "payload")

    def __init__(self, lsn: int, payload: dict):
        self.lsn = lsn
        self.payload = payload

    def __repr__(self):
        return f"WalRecord(lsn={self.lsn}, op={self.payload.get('op')!r})"


class WriteAheadLog:
    """Appender over one WAL file.  Not thread-safe (callers serialize,
    matching StreamingIndex's single-writer model)."""

    def __init__(self, path: str | os.PathLike, *, base_lsn: int = 0,
                 sync: bool = True, fsync_observer=None):
        """Open ``path`` for append, creating it (with a fresh header)
        if absent.  ``sync=False`` skips the per-append fsync — the
        WAL-off mode measured by ``benchmarks/resilience_cost.py``.
        ``fsync_observer(seconds)`` feeds the wal_fsync_seconds metric.
        """
        self.path = Path(path)
        self.sync = bool(sync)
        self._fsync_observer = fsync_observer
        self.appended = 0  # records appended via this handle
        if self.path.exists() and self.path.stat().st_size >= HEADER_SIZE:
            base, records, valid = scan_wal(self.path)
            if valid < self.path.stat().st_size:
                # torn tail from a previous crash: cut it before append
                truncate_wal(self.path, valid)
            self.base_lsn = base
            self.next_lsn = records[-1].lsn + 1 if records else base
            self._f = open(self.path, "ab")
        else:
            self.base_lsn = int(base_lsn)
            self.next_lsn = self.base_lsn
            self._f = open(self.path, "wb")
            self._f.write(MAGIC + struct.pack("<Q", self.base_lsn))
            self._f.flush()
            if self.sync:
                os.fsync(self._f.fileno())

    def append(self, payload: dict) -> int:
        """Append one record durably; returns its LSN.  Raises before
        writing anything if a chaos fault is scheduled at wal.append
        (the pre-write kill point: the op never reached the log)."""
        chaos.hit("wal.append")
        body = msgpack.packb(payload)
        lsn = self.next_lsn
        rec = RECORD_HEADER.pack(len(body), lsn, _digest(lsn, body)) + body
        self._f.write(rec)
        self._f.flush()
        if self.sync:
            t0 = time.perf_counter()
            os.fsync(self._f.fileno())
            if self._fsync_observer is not None:
                self._fsync_observer(time.perf_counter() - t0)
        self.next_lsn = lsn + 1
        self.appended += 1
        return lsn

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            if self.sync:
                os.fsync(self._f.fileno())
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def scan_wal(path: str | os.PathLike) -> tuple[int, list[WalRecord], int]:
    """Sequentially decode a WAL file.

    Returns ``(base_lsn, records, valid_bytes)`` where ``valid_bytes``
    is the offset of the first torn/invalid byte (== file size when the
    log is clean).  Scanning stops at the first record that is
    incomplete, fails its digest, or breaks LSN monotonicity — a torn
    tail is DETECTED, never replayed.
    """
    data = Path(path).read_bytes()
    if len(data) < HEADER_SIZE or data[: len(MAGIC)] != MAGIC:
        raise ValueError(f"{path}: not a WAL file (bad magic)")
    (base_lsn,) = struct.unpack_from("<Q", data, len(MAGIC))
    records: list[WalRecord] = []
    off = HEADER_SIZE
    expect = base_lsn
    while off + RECORD_HEADER.size <= len(data):
        plen, lsn, digest = RECORD_HEADER.unpack_from(data, off)
        body_off = off + RECORD_HEADER.size
        if body_off + plen > len(data):
            break  # torn: payload ran past EOF
        body = data[body_off: body_off + plen]
        if lsn != expect or _digest(lsn, body) != digest:
            break  # torn/corrupt: stop, do not trust anything past here
        try:
            payload = msgpack.unpackb(body)
        except Exception:
            break
        records.append(WalRecord(lsn, payload))
        off = body_off + plen
        expect = lsn + 1
    return base_lsn, records, off


def truncate_wal(path: str | os.PathLike, valid_bytes: int) -> None:
    """Physically cut a torn tail so it can never be replayed."""
    with open(path, "r+b") as f:
        f.truncate(valid_bytes)
        f.flush()
        os.fsync(f.fileno())
    fsync_path(path)
