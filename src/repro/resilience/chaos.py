"""Deterministic fault injection: seeded plans over named sites.

ReFrame-style parameterized failure testing (DESIGN.md §14): instead of
ad-hoc mocks, failure modes are first-class, repeatable test
parameters.  Code paths that can fail in production carry a *site* —
a cheap ``chaos.hit("wal.append")`` call (one global ``is None`` check
when no plan is installed) — and a test or drill installs a
:class:`FaultPlan` that schedules faults against those sites:

    kind        effect at the site
    --------    ----------------------------------------------------
    error       raise ChaosError (a crash / kill point)
    latency     sleep ``latency_s``; when the caller passed a budget
                and the injected latency exceeds it, sleep only the
                budget and raise ChaosLatencyExceeded — the model of
                a straggler call abandoned at its deadline
    bitflip     flip ``flip_bits`` random bits of a byte payload
                (``chaos.transform`` sites — checksums must catch it)
    drop        ``chaos.dropped(site)`` returns True — the operation
                is silently skipped (a lost flush)
    nonfinite   ``chaos.poisoned(site)`` returns True — the caller
                substitutes a NaN/Inf payload (a poisoned query)

Schedules are deterministic: ``at=n`` fires on the n-th (0-based)
matching access of the site, ``prob=p`` fires per access from the
plan's seeded RNG, and ``times`` caps total firings.  A plan's whole
trajectory is a pure function of (specs, seed, access sequence), so
every chaos test and the CI drill (``scripts/chaos_drill.py``) replay
exactly.

Instrumented sites (the seams named in ISSUE 9):

    wal.append        before a WAL record is written   (kill point)
    stream.apply      after the WAL write, before the in-memory
                      mutation                          (kill point)
    stream.flush      delta seal                        (drop)
    snapshot.write    before snapshot payload files are written
    snapshot.commit   before the COMMIT marker
    segment.load      snapshot segment bytes on read    (bitflip)
    serve.flush       scheduler bucket flush            (drop)
    serve.search      primary-tier index call           (error/latency)
    serve.degraded    degraded-tier index call          (error/latency)
    serve.cache       hot-query cache probe             (error)
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import time
from typing import Iterable, Sequence

__all__ = ["FaultSpec", "FaultPlan", "ChaosError", "ChaosLatencyExceeded",
           "install", "uninstall", "active", "current_plan", "hit",
           "transform", "dropped", "poisoned", "KNOWN_SITES"]

#: every site the codebase instruments, with the fault kinds that are
#: meaningful there — the vocabulary ``FaultPlan.seeded`` draws from
KNOWN_SITES: dict[str, tuple[str, ...]] = {
    "wal.append": ("error", "latency"),
    "stream.apply": ("error",),
    "stream.flush": ("drop",),
    "snapshot.write": ("error",),
    "snapshot.commit": ("error",),
    "segment.load": ("bitflip",),
    "serve.flush": ("drop",),
    "serve.search": ("error", "latency"),
    "serve.degraded": ("error", "latency"),
    "serve.cache": ("error",),
}


class ChaosError(RuntimeError):
    """An injected fault (the simulated crash/failure)."""

    def __init__(self, site: str, message: str = "injected fault"):
        self.site = site
        super().__init__(f"{message} at site {site!r}")


class ChaosLatencyExceeded(ChaosError):
    """An injected straggler exceeded the caller's budget — the model
    of a timed-out call abandoned at its deadline."""

    def __init__(self, site: str, latency_s: float, budget_s: float):
        self.latency_s = latency_s
        self.budget_s = budget_s
        super().__init__(site, f"injected {latency_s * 1e3:.1f}ms straggler "
                               f"past {budget_s * 1e3:.1f}ms budget")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: where, what, and when it fires."""

    site: str
    kind: str  # "error" | "latency" | "bitflip" | "drop" | "nonfinite"
    at: int | None = None  # fire on the at-th (0-based) matching access
    prob: float = 0.0  # per-access probability when ``at`` is None
    times: int = 1  # total firing cap (<=0 → unlimited)
    latency_s: float = 0.0  # kind="latency"
    flip_bits: int = 1  # kind="bitflip"

    def __post_init__(self):
        if self.kind not in ("error", "latency", "bitflip", "drop",
                             "nonfinite"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


#: accessor → the fault kinds it consumes (each accessor advances only
#: its own specs' hit counters, so mixing accessors at one site stays
#: deterministic)
_ACCESSOR_KINDS = {
    "hit": ("error", "latency"),
    "transform": ("bitflip",),
    "dropped": ("drop",),
    "poisoned": ("nonfinite",),
}


class FaultPlan:
    """A deterministic, seeded schedule of faults over named sites."""

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._hits = [0] * len(self.specs)  # matching accesses per spec
        self._fired = [0] * len(self.specs)
        self.sleep = time.sleep  # injectable for tests

    # -- bookkeeping -----------------------------------------------------

    def fired(self) -> dict[tuple[str, str], int]:
        """(site, kind) → times fired so far."""
        out: dict[tuple[str, str], int] = {}
        for spec, n in zip(self.specs, self._fired):
            if n:
                key = (spec.site, spec.kind)
                out[key] = out.get(key, 0) + n
        return out

    def _due(self, site: str, kinds: tuple[str, ...]) -> FaultSpec | None:
        """Advance counters for matching specs; return the first spec
        that fires on this access (at most one per access)."""
        fired = None
        for i, spec in enumerate(self.specs):
            if spec.site != site or spec.kind not in kinds:
                continue
            n = self._hits[i]
            self._hits[i] += 1
            if spec.times > 0 and self._fired[i] >= spec.times:
                continue
            due = (n == spec.at if spec.at is not None
                   else self._rng.random() < spec.prob)
            if due and fired is None:
                self._fired[i] += 1
                fired = spec
        return fired

    # -- accessors -------------------------------------------------------

    def on_hit(self, site: str, budget_s: float | None = None) -> None:
        spec = self._due(site, _ACCESSOR_KINDS["hit"])
        if spec is None:
            return
        if spec.kind == "error":
            raise ChaosError(site)
        # latency: sleep the straggler, but never past the caller's
        # budget — past it the call is modeled as abandoned
        if budget_s is not None and spec.latency_s > budget_s:
            self.sleep(budget_s)
            raise ChaosLatencyExceeded(site, spec.latency_s, budget_s)
        self.sleep(spec.latency_s)

    def on_bytes(self, site: str, data: bytes) -> bytes:
        spec = self._due(site, _ACCESSOR_KINDS["transform"])
        if spec is None or not data:
            return data
        buf = bytearray(data)
        for _ in range(max(spec.flip_bits, 1)):
            pos = self._rng.randrange(len(buf))
            buf[pos] ^= 1 << self._rng.randrange(8)
        return bytes(buf)

    def on_dropped(self, site: str) -> bool:
        return self._due(site, _ACCESSOR_KINDS["dropped"]) is not None

    def on_poisoned(self, site: str) -> bool:
        return self._due(site, _ACCESSOR_KINDS["poisoned"]) is not None

    # -- constructors ----------------------------------------------------

    @classmethod
    def seeded(cls, seed: int, sites: Iterable[str] | None = None, *,
               prob: float = 0.05, times: int = 3,
               latency_s: float = 0.05) -> "FaultPlan":
        """A randomized drill plan: for each site, one probabilistic
        spec per kind that site supports.  Same seed → same plan AND
        same firing trajectory."""
        specs = []
        for site in (sites if sites is not None else sorted(KNOWN_SITES)):
            for kind in KNOWN_SITES.get(site, ("error",)):
                specs.append(FaultSpec(site, kind, prob=prob, times=times,
                                       latency_s=latency_s))
        return cls(specs, seed=seed)


# -- process-global installation --------------------------------------------

_PLAN: FaultPlan | None = None


def install(plan: FaultPlan) -> None:
    global _PLAN
    _PLAN = plan


def uninstall() -> None:
    global _PLAN
    _PLAN = None


def current_plan() -> FaultPlan | None:
    return _PLAN


@contextlib.contextmanager
def active(plan: FaultPlan):
    """Install ``plan`` for the duration of the block."""
    prev = _PLAN
    install(plan)
    try:
        yield plan
    finally:
        install(prev) if prev is not None else uninstall()


def hit(site: str, budget_s: float | None = None) -> None:
    """Fault hook: raises / sleeps per the installed plan (~free when
    none is installed — one global read)."""
    if _PLAN is not None:
        _PLAN.on_hit(site, budget_s=budget_s)


def transform(site: str, data: bytes) -> bytes:
    """Byte-corruption hook: returns ``data``, possibly bit-flipped."""
    if _PLAN is not None:
        return _PLAN.on_bytes(site, data)
    return data


def dropped(site: str) -> bool:
    """True when a scheduled "drop" fault fires — caller skips the op."""
    return _PLAN is not None and _PLAN.on_dropped(site)


def poisoned(site: str) -> bool:
    """True when a scheduled "nonfinite" fault fires — caller poisons
    its payload (e.g. substitutes NaN into a query)."""
    return _PLAN is not None and _PLAN.on_poisoned(site)
