"""Durable filesystem primitives shared by WAL, snapshots, checkpoints.

POSIX gives no single "write this durably" call — durability is a
protocol: flush the file's bytes (``fsync`` on the fd), then flush the
DIRECTORY entry that names it (``fsync`` on the directory fd), and only
then write the marker that declares the payload complete.  Skipping any
step re-opens the classic torn-commit window: after a power loss the
marker can survive while the payload it vouches for did not.

``commit_dir`` packages the full idiom used by both the snapshot writer
(``resilience.snapshot``) and the training checkpointer
(``launch.checkpoint``):

    1. fsync every payload file in the staging dir
    2. fsync the staging dir (directory entries now durable)
    3. write the COMMIT marker, fsync it, fsync the dir again
    4. rename staging → final (atomic on POSIX)
    5. fsync the parent dir (the rename itself now durable)

A reader that requires the COMMIT marker therefore never observes a
committed-but-torn payload.
"""
from __future__ import annotations

import os
from pathlib import Path

__all__ = ["fsync_path", "fsync_dir", "write_file_durable", "commit_dir",
           "COMMIT_MARKER"]

COMMIT_MARKER = "COMMIT"


def fsync_path(path: str | os.PathLike) -> None:
    """fsync a regular file's contents to stable storage."""
    fd = os.open(os.fspath(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str | os.PathLike) -> None:
    """fsync a directory — makes its entries (creates/renames) durable."""
    fd = os.open(os.fspath(path), os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_file_durable(path: str | os.PathLike, data: bytes) -> None:
    """Write ``data`` to ``path`` and fsync the file (not the dir)."""
    path = os.fspath(path)
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def commit_dir(tmp: str | os.PathLike, final: str | os.PathLike, *,
               marker: str = COMMIT_MARKER) -> Path:
    """Durably commit staging dir ``tmp`` as ``final``.

    Payload files are fsynced BEFORE the marker is written (closing the
    torn-commit window), the marker and directory are fsynced, and the
    staging dir is atomically renamed into place.  An existing ``final``
    is replaced only after the new payload is fully durable.  Returns
    the final path.
    """
    import shutil

    tmp, final = Path(tmp), Path(final)
    for p in sorted(tmp.rglob("*")):
        if p.is_file() and p.name != marker:
            fsync_path(p)
    fsync_dir(tmp)
    write_file_durable(tmp / marker, b"ok\n")
    fsync_dir(tmp)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    fsync_dir(final.parent)
    return final
