"""repro.resilience — durability and fault tolerance (DESIGN.md §14).

Three legs:

* **durability** (:mod:`wal`, :mod:`snapshot`, :mod:`recovery`): a
  checksummed write-ahead log + atomic COMMIT-marker snapshots for
  StreamingIndex.  WAL-before-memory ordering means a crash at any
  instant loses at most the op whose record never reached disk;
  ``recover(dir)`` replays the WAL tail over the newest verified
  snapshot and reports what it did.
* **fault injection** (:mod:`chaos`): deterministic seeded FaultPlans
  over named sites — crashes, stragglers, bit flips, dropped flushes,
  poisoned queries — driving both tests/test_resilience.py and the
  scripts/chaos_drill.py CI drill.
* **serve hardening** (:mod:`breaker` + repro.serve.scheduler): the
  retry/hedge ladder, circuit breaker around the degraded tier, query
  validation, and poison-batch quarantine.

Durable streaming quickstart::

    from repro import build_index, IndexConfig
    from repro.resilience import recover

    cfg = IndexConfig(backend="streaming",
                      options={"durability": {"dir": "/data/idx",
                                              "snapshot_every": 4096}})
    index = build_index(seed_rows, cfg)
    index.insert(more_rows)          # WAL'd before visible
    # ... process dies ...
    index, report = recover("/data/idx")
"""
from .breaker import CircuitBreaker
from .chaos import ChaosError, ChaosLatencyExceeded, FaultPlan, FaultSpec
from .fsio import commit_dir, fsync_dir, fsync_path, write_file_durable
from .recovery import (DurabilityManager, RecoveryError, RecoveryReport,
                       recover)
from .snapshot import (CorruptSegmentError, latest_snapshot, load_snapshot,
                       write_snapshot)
from .wal import WriteAheadLog, scan_wal

__all__ = [
    "CircuitBreaker",
    "ChaosError", "ChaosLatencyExceeded", "FaultPlan", "FaultSpec",
    "commit_dir", "fsync_dir", "fsync_path", "write_file_durable",
    "DurabilityManager", "RecoveryError", "RecoveryReport", "recover",
    "CorruptSegmentError", "latest_snapshot", "load_snapshot",
    "write_snapshot",
    "WriteAheadLog", "scan_wal",
]
